//! The serving-side prediction stage (ADR 005): bridges the unified
//! [`crate::predictor::Predictor`] surface onto the live pipeline.
//!
//! Both prediction families reach the planner through this module:
//!
//! * **Token-to-Expert** — [`TepHead`] runs the AOT-compiled predictor op
//!   on every sequence's embeddings (§3.1: *before attention*) and
//!   converts the logits into ranked per-token top-k sets plus
//!   per-(layer, expert) slot counts, using the same
//!   [`crate::predictor::rank_topk_f32`] kernel the offline zoo ranks
//!   with. This used to be bespoke plumbing inside `pipeline.rs`; it now
//!   lives beside the predictor layer it belongs to.
//! * **Distribution-Only** — [`expected_counts`] converts a share
//!   distribution (a [`crate::predictor::Predictor::predict_distribution`]
//!   output) into expected per-expert slot counts for Algorithm 1,
//!   conserving the slot total exactly.

use anyhow::Result;

use crate::predictor::rank_topk_f32;
use crate::runtime::{Engine, HostTensor, In};

/// The AOT Token-to-Expert predictor head: op + weight names plus the
/// logits→top-k conversion. Holds no engine — the coordinator lends its
/// leader engine per call, so the head stays borrow-free state.
pub(crate) struct TepHead {
    head_names: Vec<String>,
    n_layers: usize,
    n_experts: usize,
    top_k: usize,
}

impl TepHead {
    pub(crate) fn new(n_layers: usize, n_experts: usize, top_k: usize) -> TepHead {
        TepHead {
            head_names: (0..n_layers)
                .map(|l| format!("predictor.head.{l}"))
                .collect(),
            n_layers,
            n_experts,
            top_k: top_k.clamp(1, n_experts.max(1)),
        }
    }

    /// Run the predictor on every sequence's embeddings. Returns predicted
    /// slot counts per (layer, expert) plus the ranked per-token top-k
    /// predictions `[layer][seq][token][rank]` the speculative scatter
    /// confirms against (rank 0 = predictor argmax). The router routes
    /// each token to `top_k` experts, so the predictor forecasts the
    /// token's full top-k set — one predicted slot per rank — rather than
    /// charging all `top_k` slots to the argmax expert (the ADR-003
    /// follow-up). `hidden[i]` holds `≥ n_real[i]` embedded rows.
    #[allow(clippy::type_complexity)]
    pub(crate) fn predict(
        &self,
        leader: &mut Engine,
        hidden: &[HostTensor],
        n_real: &[usize],
    ) -> Result<(Vec<Vec<usize>>, Vec<Vec<Vec<Vec<u8>>>>)> {
        let e = self.n_experts;
        let n_layers = self.n_layers;
        let top_k = self.top_k;
        let mut counts = vec![vec![0usize; e]; n_layers];
        let mut predicted: Vec<Vec<Vec<Vec<u8>>>> = (0..n_layers)
            .map(|_| Vec::with_capacity(hidden.len()))
            .collect();
        // The rank buffer is reused across tokens so the timed loop stays
        // allocation-free bar the stored per-token rank vectors.
        let mut order: Vec<usize> = Vec::with_capacity(e);
        for (seq, &n) in hidden.iter().zip(n_real) {
            let s_rows = seq.rows();
            let mut ins: Vec<In<'_>> = vec![
                In::T(seq),
                In::W("predictor.w1"),
                In::W("predictor.b1"),
            ];
            for name in &self.head_names {
                ins.push(In::W(name));
            }
            let logits = leader.call("predictor", &ins)?.remove(0);
            // logits [L, S, E]: ranked top-k per (layer, real token) via
            // the shared predictor-layer kernel (total order, O(e)/token).
            for l in 0..n_layers {
                let mut seq_pred = Vec::with_capacity(n.min(s_rows));
                for t in 0..n.min(s_rows) {
                    let base = (l * s_rows + t) * e;
                    let row = &logits.data[base..base + e];
                    let ranked: Vec<u8> = rank_topk_f32(row, top_k, &mut order)
                        .iter()
                        .map(|&arg| {
                            counts[l][arg] += 1;
                            arg as u8
                        })
                        .collect();
                    seq_pred.push(ranked);
                }
                predicted[l].push(seq_pred);
            }
        }
        Ok((counts, predicted))
    }
}

/// Convert a per-expert share distribution into expected slot counts that
/// sum to exactly `total_slots` (rounding drift is repaired by walking
/// the experts round-robin) — the Distribution-Only half of the predict
/// stage, shared by the placement manager's per-layer planning.
pub fn expected_counts(probs: &[f64], total_slots: usize) -> Vec<usize> {
    let mut counts: Vec<usize> = probs
        .iter()
        .map(|p| (p * total_slots as f64).round() as usize)
        .collect();
    let mut diff = total_slots as i64 - counts.iter().sum::<usize>() as i64;
    let mut i = 0;
    while diff != 0 && !counts.is_empty() {
        let idx = i % counts.len();
        if diff > 0 {
            counts[idx] += 1;
            diff -= 1;
        } else if counts[idx] > 0 {
            counts[idx] -= 1;
            diff += 1;
        }
        i += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expected_counts_conserve_total() {
        let probs = [0.5, 0.25, 0.125, 0.125];
        for total in [0usize, 1, 7, 64, 513] {
            let c = expected_counts(&probs, total);
            assert_eq!(c.iter().sum::<usize>(), total, "total={total}");
        }
        // Rounding drift repaired: a distribution whose rounds overshoot.
        let skewed = [0.334, 0.333, 0.333];
        let c = expected_counts(&skewed, 100);
        assert_eq!(c.iter().sum::<usize>(), 100);
    }

    #[test]
    fn expected_counts_track_shares() {
        let probs = [0.75, 0.25];
        let c = expected_counts(&probs, 400);
        assert_eq!(c, vec![300, 100]);
    }

    #[test]
    fn tep_head_names_cover_layers() {
        let head = TepHead::new(3, 8, 2);
        assert_eq!(head.head_names.len(), 3);
        assert_eq!(head.head_names[2], "predictor.head.2");
        assert_eq!(head.top_k, 2);
        // top_k clamps into [1, e].
        assert_eq!(TepHead::new(1, 4, 0).top_k, 1);
        assert_eq!(TepHead::new(1, 4, 9).top_k, 4);
    }
}
