//! Top-k routing policy (rust side — the router *logits* come from the
//! AOT Pallas kernel; selection and gate computation are coordinator
//! policy, so they live here where the duplication plan can see them).

/// One routed token slot: token `token_idx` of sequence `seq_idx` goes to
/// `expert` with combine weight `gate`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Slot {
    pub seq_idx: usize,
    pub token_idx: usize,
    pub expert: u8,
    pub gate: f32,
}

/// Mixtral-style top-k: pick the k largest logits per token, softmax over
/// just those to produce gates.
pub fn top_k_route(
    logits_row: &[f32],
    k: usize,
) -> Vec<(u8, f32)> {
    debug_assert!(k >= 1 && k <= logits_row.len());
    let mut idx: Vec<usize> = (0..logits_row.len()).collect();
    idx.sort_by(|&a, &b| logits_row[b].total_cmp(&logits_row[a]));
    let top = &idx[..k];
    let max = logits_row[top[0]];
    let exps: Vec<f32> = top.iter().map(|&i| (logits_row[i] - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    top.iter()
        .zip(&exps)
        .map(|(&i, &e)| (i as u8, e / sum))
        .collect()
}

/// Route a whole sequence's logits ([tokens × experts] row-major, only the
/// first `n_real` tokens) into slots.
pub fn route_sequence(
    seq_idx: usize,
    logits: &[f32],
    n_experts: usize,
    n_real: usize,
    k: usize,
) -> Vec<Slot> {
    let mut slots = Vec::with_capacity(n_real * k);
    for t in 0..n_real {
        let row = &logits[t * n_experts..(t + 1) * n_experts];
        for (expert, gate) in top_k_route(row, k) {
            slots.push(Slot {
                seq_idx,
                token_idx: t,
                expert,
                gate,
            });
        }
    }
    slots
}

/// Per-expert slot counts (the input to Algorithm 1 at serving time).
pub fn expert_counts(slots: &[Slot], n_experts: usize) -> Vec<usize> {
    let mut counts = vec![0usize; n_experts];
    for s in slots {
        counts[s.expert as usize] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_picks_largest_and_normalises() {
        let logits = [0.1, 2.0, -1.0, 1.5];
        let picks = top_k_route(&logits, 2);
        assert_eq!(picks[0].0, 1);
        assert_eq!(picks[1].0, 3);
        let gate_sum: f32 = picks.iter().map(|p| p.1).sum();
        assert!((gate_sum - 1.0).abs() < 1e-6);
        assert!(picks[0].1 > picks[1].1);
    }

    #[test]
    fn top_1_gate_is_one() {
        let picks = top_k_route(&[0.0, 5.0, 1.0], 1);
        assert_eq!(picks, vec![(1, 1.0)]);
    }

    #[test]
    fn route_sequence_only_real_tokens() {
        let n_experts = 4;
        // 3 tokens, only 2 real.
        let logits = vec![
            1.0, 0.0, 0.0, 0.0, // t0 -> e0
            0.0, 0.0, 3.0, 0.0, // t1 -> e2
            9.0, 9.0, 9.0, 9.0, // t2 padding, must be ignored
        ];
        let slots = route_sequence(7, &logits, n_experts, 2, 2);
        assert_eq!(slots.len(), 4);
        assert!(slots.iter().all(|s| s.seq_idx == 7 && s.token_idx < 2));
        assert_eq!(slots[0].expert, 0);
        assert_eq!(slots[2].expert, 2);
        let counts = expert_counts(&slots, n_experts);
        assert_eq!(counts.iter().sum::<usize>(), 4);
    }
}
