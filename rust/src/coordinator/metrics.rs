//! Serving metrics: per-round phase timings, per-worker load, and the
//! aggregate report the E2E example prints (latency / throughput /
//! imbalance — the quantities the paper's evaluation is about). Reports
//! also serialize to the `moe-gps/serve-report/v1` JSON schema (ADR 005)
//! carrying the measured constants, the fit-vs-holdout calibration check
//! and the controller decision trace that `advise --from-serve` consumes.

use super::controller::ControllerReport;
use crate::gps::online::{calibration_check, OnlineCalibrator, WindowSample};
use crate::util::json::Value;
use crate::util::stats;

/// Schema tag of the serve-report JSON (`serve --report`).
pub const REPORT_SCHEMA: &str = "moe-gps/serve-report/v1";

/// Run-level context recorded into the report: which serving phase and
/// engine regime produced the measurements (what `advise --from-serve`
/// prices the calibrated guideline map under).
#[derive(Clone, Debug, Default)]
pub struct ReportMeta {
    /// "prefill" | "decode".
    pub phase: String,
    pub workers: usize,
    pub lookahead: usize,
    pub speculative: bool,
    pub memory_cap_bytes: Option<u64>,
    /// Whether the online strategy controller was driving (`--adaptive`).
    pub adaptive: bool,
    /// Forecast horizon the placement planned for (0 = reactive, ADR 006).
    pub horizon: usize,
    /// Compute pool threads the kernels ran on (0 = not recorded, e.g.
    /// reports parsed from pre-ADR-007 runs).
    pub threads: usize,
    /// Whether pool helpers were pinned to cores (ADR 007).
    pub pinned: bool,
    /// Resolved SIMD dispatch tier ("scalar" | "avx2+fma" | "neon") —
    /// the kernel regime the measured constants were calibrated under.
    pub simd_tier: String,
    /// Micro-batch wavefront depth the run was served at (ADR 010;
    /// 0 = not recorded, 1 = serial).
    pub microbatch: usize,
}

impl ReportMeta {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("phase", Value::Str(self.phase.clone()))
            .set("workers", Value::Num(self.workers as f64))
            .set("lookahead", Value::Num(self.lookahead as f64))
            .set("speculative", Value::Bool(self.speculative))
            .set(
                "memory_cap_bytes",
                match self.memory_cap_bytes {
                    Some(b) => Value::Num(b as f64),
                    None => Value::Null,
                },
            )
            .set("adaptive", Value::Bool(self.adaptive))
            .set("horizon", Value::Num(self.horizon as f64))
            .set("threads", Value::Num(self.threads as f64))
            .set("pinned", Value::Bool(self.pinned))
            .set("simd_tier", Value::Str(self.simd_tier.clone()))
            .set("microbatch", Value::Num(self.microbatch as f64));
        v
    }

    /// One-line kernel-regime suffix for the human summaries; empty when
    /// the runtime fields were never recorded (hand-built test reports).
    fn runtime_suffix(&self) -> String {
        if self.threads == 0 {
            return String::new();
        }
        format!(
            "\n  kernels: simd={} threads={} pinned={}",
            if self.simd_tier.is_empty() { "?" } else { &self.simd_tier },
            self.threads,
            self.pinned,
        )
    }
}

/// Metrics for one serving round.
#[derive(Clone, Debug, Default)]
pub struct RoundMetrics {
    pub n_seqs: usize,
    pub n_tokens: usize,
    pub n_slots: usize,
    pub embed_s: f64,
    pub predictor_s: f64,
    pub attention_s: f64,
    pub router_s: f64,
    pub plan_s: f64,
    pub ffn_wall_s: f64,
    pub combine_s: f64,
    pub total_s: f64,
    /// Busy seconds per worker (summed across layers).
    pub worker_busy_s: Vec<f64>,
    /// Token-slots processed per worker.
    pub worker_slots: Vec<usize>,
    /// Total duplication-transfer bytes (= hidden + exposed).
    pub upload_bytes: u64,
    /// Transfer bytes whose upload completed under the lookahead overlap
    /// window (prewarm acks that arrived before the FFN phase needed the
    /// weights — ADR 002).
    pub hidden_upload_bytes: u64,
    /// Transfer bytes that landed on the critical path: prewarm acks the
    /// FFN phase had to block on, plus cold uploads inside
    /// `WorkerMsg::RunBatch`.
    pub exposed_upload_bytes: u64,
    /// Worker seconds spent on transfers that were overlapped (hidden).
    pub hidden_transfer_s: f64,
    /// Leader wall seconds stalled waiting on transfers (exposed).
    pub exposed_transfer_s: f64,
    /// Replicas added by the planner this round.
    pub replicas_added: usize,
    /// Observed routing skewness averaged over layers.
    pub routing_skew: f64,
    /// Tile buffers freshly heap-allocated on the FFN dispatch path
    /// (gather/pad/scatter) — 0 in steady state once the pool is warm
    /// (ADR 003).
    pub tile_allocs: u64,
    /// Tile buffers recycled from the coordinator's tile pool.
    pub tile_reuses: u64,
    /// Slots dispatched speculatively (layer-L+1 expert predicted during
    /// layer L's FFN phase and confirmed anywhere in the routed top-k —
    /// §3.1 TEP, ADR 003/004).
    pub spec_dispatch_slots: usize,
    /// Slots that took the repair pass (mispredicted or extra top-k).
    pub spec_repair_slots: usize,
    /// Replica weights evicted by the residency LRU (capacity pressure
    /// plus plan-shrink evictions — ADR 004).
    pub evictions: u64,
    /// Bytes re-uploaded for replicas the cap had evicted (refetches).
    pub refetch_upload_bytes: u64,
    /// Peak per-worker resident replica bytes (the `--memory-cap`
    /// acceptance number: ≤ the cap whenever no pinned overflow occurred).
    pub resident_high_water_bytes: u64,
    /// Routed slots that carried a per-token prediction (TEP) — the
    /// top-k hit rate's denominator (ADR 005).
    pub pred_slots: usize,
    /// Tokens that carried a prediction — the top-1 denominator, so the
    /// realized argmax accuracy matches the offline harness's per-token
    /// definition.
    pub pred_tokens: usize,
    /// Slots whose routed expert appeared anywhere in the predicted
    /// top-k set.
    pub pred_topk_hits: usize,
    /// Tokens whose routed expert set contained the predictor argmax.
    pub pred_top1_hits: usize,
    /// Mean per-layer L1 error between predicted and routed per-expert
    /// shares (DOP + TEP; the live Table-1 metric — ADR 005).
    pub pred_share_l1: f64,
    /// Layers that carried predicted counts (0 under NoPrediction).
    pub pred_share_layers: usize,
    /// Mean realized forecast L1 error over the horizon forecasts that
    /// matured this round: the h-step-ahead share forecast parked at plan
    /// time vs the shares actually routed h observes later (ADR 006;
    /// 0 layers ⇒ no forecast matured, e.g. horizon 0).
    pub forecast_l1: f64,
    /// (layer, forecast) pairs that matured and were scored this round.
    pub forecast_layers: usize,
    /// Workers newly detected dead this round (ADR 008).
    pub worker_deaths: u64,
    /// Slots re-sent to a surviving replica after their owner died or
    /// their reply was lost.
    pub redispatched_slots: usize,
    /// Reply-deadline timeouts waited through (straggler retries).
    pub retry_count: u64,
    /// Prewarm acks abandoned: deadline exhausted or owner died. Each
    /// abandoned pair is marked residency-unknown so later dispatch
    /// re-uploads cold instead of trusting a pin forever.
    pub prewarm_timeouts: u64,
    /// Sequences evicted back to the waiting queue (requeued, not lost).
    pub requeued_seqs: usize,
    /// The round ran on a degraded fleet: a worker died during it, or
    /// fewer workers than configured were alive when it started.
    pub degraded: bool,
    /// Host bytes deep-copied on the coordinator↔worker data plane
    /// (ADR 009): only the FFN gather packing routed rows into arena
    /// slabs — steady state is exactly Σ n_slots × d_model × 4.
    pub bytes_copied: u64,
    /// Host bytes moved by reference instead of copied (ADR 009): the
    /// `Arc`-shared attention fan-out batches, counted once per
    /// receiving worker.
    pub bytes_shared: u64,
    /// Coalesced `WorkerMsg::RunBatch` messages sent — one per
    /// (layer wave, worker with assigned groups) under ADR 009. The
    /// wavefront dispatches per micro-batch, so this grows ~K-fold at
    /// `--microbatch K` (and is pinned unchanged at K=1).
    pub ffn_messages: u64,
    /// Leader wall seconds blocked inside `recv_timeout` waiting for FFN
    /// replies (ADR 010) — the serialization the wavefront attacks.
    pub leader_stall_s: f64,
    /// Wall seconds covered by the per-layer router→combine windows that
    /// `worker_idle_frac` is normalized over (ADR 010).
    pub wavefront_window_s: f64,
    /// Fraction of worker capacity idle inside the wavefront windows:
    /// 1 − Σ busy / (window × workers), clamped to [0, 1]. Drops as
    /// `--microbatch K` overlaps routing with in-flight FFN slabs.
    pub worker_idle_frac: f64,
    /// Peak FFN slabs checked out of the tile pool at once (ADR 010) —
    /// bounds how far concurrent micro-batches grow the arena.
    pub tile_peak: u64,
}

impl RoundMetrics {
    /// Load imbalance of the FFN phase: max worker busy / mean busy
    /// (1.0 = perfectly balanced — the paper's skewness, measured on the
    /// executed system rather than the trace).
    pub fn busy_imbalance(&self) -> f64 {
        let mean = stats::mean(&self.worker_busy_s);
        if mean <= 0.0 {
            return 1.0;
        }
        self.worker_busy_s.iter().cloned().fold(0.0, f64::max) / mean
    }

    /// Slot imbalance: max slots / mean slots per worker.
    pub fn slot_imbalance(&self) -> f64 {
        stats::skewness_of_counts(&self.worker_slots)
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.total_s <= 0.0 {
            return 0.0;
        }
        self.n_tokens as f64 / self.total_s
    }
}

/// Run-level robustness aggregates carried at the serve-report root
/// (ADR 008). All-zero on healthy runs, and pre-ADR-008 readers simply
/// ignore the extra keys, so `moe-gps/serve-report/v1` stays
/// backward-readable.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultSummary {
    pub worker_deaths: u64,
    pub redispatched_slots: usize,
    pub retries: u64,
    pub prewarm_timeouts: u64,
    pub requeued_seqs: usize,
    /// Rounds/steps that ran on a degraded fleet.
    pub degraded_samples: usize,
    /// Admitted sequences that neither finished nor remained queued at
    /// the end of the run — the chaos gate requires 0 (decode runs only;
    /// prefill rounds have no requeue path).
    pub lost_seqs: u64,
}

impl FaultSummary {
    pub fn any(&self) -> bool {
        *self != FaultSummary::default()
    }

    fn summary_suffix(&self) -> String {
        if !self.any() {
            return String::new();
        }
        format!(
            "\n  faults: deaths={} redispatched={} retries={} \
             prewarm timeouts={} requeued={} degraded windows={} lost={}",
            self.worker_deaths,
            self.redispatched_slots,
            self.retries,
            self.prewarm_timeouts,
            self.requeued_seqs,
            self.degraded_samples,
            self.lost_seqs,
        )
    }
}

/// Data-plane copy accounting rolled up over a run (ADR 009): the
/// numbers the serve report exposes for sim transfer pricing, `advise
/// --from-serve`, and the `bench-validate --max-copied-frac` gate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CopyStats {
    /// Host bytes deep-copied (FFN slab gather only in steady state).
    pub bytes_copied: u64,
    /// Host bytes moved by `Arc` reference (attention fan-out).
    pub bytes_shared: u64,
    /// Coalesced `WorkerMsg::RunBatch` messages sent.
    pub ffn_messages: u64,
}

impl CopyStats {
    /// Fraction of data-plane bytes that were deep copies — the gated
    /// number; 0.0 when the plane moved nothing.
    pub fn copied_frac(&self) -> f64 {
        let total = self.bytes_copied + self.bytes_shared;
        if total == 0 {
            0.0
        } else {
            self.bytes_copied as f64 / total as f64
        }
    }

    fn summary_suffix(&self) -> String {
        format!(
            "  copied={} shared={} (copied frac={:.3}) ffn msgs={}",
            crate::util::human_bytes(self.bytes_copied as f64),
            crate::util::human_bytes(self.bytes_shared as f64),
            self.copied_frac(),
            self.ffn_messages,
        )
    }
}

/// Wavefront overlap accounting rolled up over a run (ADR 010): the
/// numbers the serve report exposes for the `bench-validate
/// --max-idle-frac` gate and the idle-fraction report line.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WavefrontStats {
    /// Window-weighted mean worker idle fraction: Σ(idle × window) /
    /// Σ window over rounds/steps that recorded a wavefront window;
    /// 0.0 when none did (hand-built test reports).
    pub worker_idle_frac: f64,
    /// Total leader wall seconds blocked on FFN replies.
    pub leader_stall_s: f64,
    /// Peak concurrent in-flight FFN slabs across the run.
    pub tile_peak: u64,
}

impl WavefrontStats {
    fn summary_suffix(&self) -> String {
        format!(
            "  idle frac={:.3} leader stall={} tile peak={}",
            self.worker_idle_frac,
            crate::util::human_time(self.leader_stall_s),
            self.tile_peak,
        )
    }
}

/// Window-weighted idle-fraction aggregation shared by both report kinds.
fn wavefront_stats(per_window: impl Iterator<Item = (f64, f64, f64, u64)>) -> WavefrontStats {
    let mut out = WavefrontStats::default();
    let (mut idle_weighted, mut window_total) = (0.0f64, 0.0f64);
    for (idle, window, stall, peak) in per_window {
        if window > 0.0 {
            idle_weighted += idle * window;
            window_total += window;
        }
        out.leader_stall_s += stall;
        out.tile_peak = out.tile_peak.max(peak);
    }
    if window_total > 0.0 {
        out.worker_idle_frac = idle_weighted / window_total;
    }
    out
}

/// Aggregate over a whole serve run.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    pub strategy: String,
    pub rounds: Vec<RoundMetrics>,
    /// Decision trace + calibrated snapshots when `--adaptive` drove the
    /// run (ADR 005).
    pub controller: Option<ControllerReport>,
    pub meta: ReportMeta,
}

impl ServeReport {
    pub fn total_tokens(&self) -> usize {
        self.rounds.iter().map(|r| r.n_tokens).sum()
    }

    pub fn total_s(&self) -> f64 {
        self.rounds.iter().map(|r| r.total_s).sum()
    }

    pub fn throughput(&self) -> f64 {
        let t = self.total_s();
        if t <= 0.0 {
            0.0
        } else {
            self.total_tokens() as f64 / t
        }
    }

    pub fn mean_round_latency_s(&self) -> f64 {
        let xs: Vec<f64> = self.rounds.iter().map(|r| r.total_s).collect();
        stats::mean(&xs)
    }

    pub fn p95_round_latency_s(&self) -> f64 {
        let xs: Vec<f64> = self.rounds.iter().map(|r| r.total_s).collect();
        stats::percentile(&xs, 95.0)
    }

    pub fn mean_busy_imbalance(&self) -> f64 {
        let xs: Vec<f64> = self.rounds.iter().map(|r| r.busy_imbalance()).collect();
        stats::mean(&xs)
    }

    pub fn mean_slot_imbalance(&self) -> f64 {
        let xs: Vec<f64> = self.rounds.iter().map(|r| r.slot_imbalance()).collect();
        stats::mean(&xs)
    }

    pub fn mean_ffn_wall_s(&self) -> f64 {
        let xs: Vec<f64> = self.rounds.iter().map(|r| r.ffn_wall_s).collect();
        stats::mean(&xs)
    }

    pub fn total_upload_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.upload_bytes).sum()
    }

    pub fn total_hidden_upload_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.hidden_upload_bytes).sum()
    }

    pub fn total_exposed_upload_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.exposed_upload_bytes).sum()
    }

    pub fn total_hidden_transfer_s(&self) -> f64 {
        self.rounds.iter().map(|r| r.hidden_transfer_s).sum()
    }

    pub fn total_exposed_transfer_s(&self) -> f64 {
        self.rounds.iter().map(|r| r.exposed_transfer_s).sum()
    }

    pub fn total_tile_allocs(&self) -> u64 {
        self.rounds.iter().map(|r| r.tile_allocs).sum()
    }

    pub fn total_tile_reuses(&self) -> u64 {
        self.rounds.iter().map(|r| r.tile_reuses).sum()
    }

    pub fn total_spec_dispatch_slots(&self) -> usize {
        self.rounds.iter().map(|r| r.spec_dispatch_slots).sum()
    }

    pub fn total_spec_repair_slots(&self) -> usize {
        self.rounds.iter().map(|r| r.spec_repair_slots).sum()
    }

    pub fn total_evictions(&self) -> u64 {
        self.rounds.iter().map(|r| r.evictions).sum()
    }

    pub fn total_refetch_upload_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.refetch_upload_bytes).sum()
    }

    /// Peak per-worker resident replica bytes across the whole run.
    pub fn resident_high_water_bytes(&self) -> u64 {
        self.rounds
            .iter()
            .map(|r| r.resident_high_water_bytes)
            .max()
            .unwrap_or(0)
    }

    pub fn total_pred_slots(&self) -> usize {
        self.rounds.iter().map(|r| r.pred_slots).sum()
    }

    /// Realized top-k set hit rate over the run (TEP only; `None` when no
    /// slot carried a prediction) — the live counterpart of the
    /// calibration harness's top-k accuracy (ADR 005).
    pub fn realized_topk_hit_rate(&self) -> Option<f64> {
        let slots = self.total_pred_slots();
        if slots == 0 {
            return None;
        }
        let hits: usize = self.rounds.iter().map(|r| r.pred_topk_hits).sum();
        Some(hits as f64 / slots as f64)
    }

    /// Realized argmax accuracy over the run (TEP only) — per token,
    /// so it is directly comparable with the offline harness's `top1`.
    pub fn realized_top1_rate(&self) -> Option<f64> {
        let tokens: usize = self.rounds.iter().map(|r| r.pred_tokens).sum();
        if tokens == 0 {
            return None;
        }
        let hits: usize = self.rounds.iter().map(|r| r.pred_top1_hits).sum();
        Some(hits as f64 / tokens as f64)
    }

    /// Mean predicted-vs-routed share L1 across rounds that carried
    /// predicted counts (DOP + TEP) — the live Table-1 error rate.
    pub fn mean_pred_share_l1(&self) -> Option<f64> {
        let xs: Vec<f64> = self
            .rounds
            .iter()
            .filter(|r| r.pred_share_layers > 0)
            .map(|r| r.pred_share_l1)
            .collect();
        if xs.is_empty() {
            None
        } else {
            Some(stats::mean(&xs))
        }
    }

    pub fn mean_routing_skew(&self) -> f64 {
        let xs: Vec<f64> = self.rounds.iter().map(|r| r.routing_skew).collect();
        stats::mean(&xs)
    }

    /// Mean realized forecast L1 error across rounds where a horizon
    /// forecast matured (`None` at horizon 0 / before any maturation) —
    /// the CI forecast-accuracy gate's number (ADR 006).
    pub fn mean_forecast_l1(&self) -> Option<f64> {
        mean_forecast_l1(self.rounds.iter().map(|r| (r.forecast_l1, r.forecast_layers)))
    }

    /// Run-level robustness aggregates (ADR 008). Prefill rounds have no
    /// requeue path, so `lost_seqs` is always 0 here.
    pub fn fault_summary(&self) -> FaultSummary {
        FaultSummary {
            worker_deaths: self.rounds.iter().map(|r| r.worker_deaths).sum(),
            redispatched_slots: self.rounds.iter().map(|r| r.redispatched_slots).sum(),
            retries: self.rounds.iter().map(|r| r.retry_count).sum(),
            prewarm_timeouts: self.rounds.iter().map(|r| r.prewarm_timeouts).sum(),
            requeued_seqs: self.rounds.iter().map(|r| r.requeued_seqs).sum(),
            degraded_samples: self.rounds.iter().filter(|r| r.degraded).count(),
            lost_seqs: 0,
        }
    }

    /// Run-level data-plane copy accounting (ADR 009).
    pub fn copy_stats(&self) -> CopyStats {
        CopyStats {
            bytes_copied: self.rounds.iter().map(|r| r.bytes_copied).sum(),
            bytes_shared: self.rounds.iter().map(|r| r.bytes_shared).sum(),
            ffn_messages: self.rounds.iter().map(|r| r.ffn_messages).sum(),
        }
    }

    /// Run-level wavefront overlap accounting (ADR 010).
    pub fn wavefront_stats(&self) -> WavefrontStats {
        wavefront_stats(self.rounds.iter().map(|r| {
            (
                r.worker_idle_frac,
                r.wavefront_window_s,
                r.leader_stall_s,
                r.tile_peak,
            )
        }))
    }

    /// Serialize to the `moe-gps/serve-report/v1` schema: run meta +
    /// aggregates + per-round calibration samples + the fitted measured
    /// constants + the fit-vs-holdout check + the controller trace — the
    /// file `advise --from-serve` renders the measured guideline map from.
    pub fn to_json(&self) -> Value {
        let samples: Vec<WindowSample> = self.rounds.iter().map(WindowSample::from).collect();
        report_json(
            &self.meta,
            &self.strategy,
            self.throughput(),
            self.total_tokens(),
            self.mean_forecast_l1(),
            &self.fault_summary(),
            &self.copy_stats(),
            &self.wavefront_stats(),
            &samples,
            self.controller.as_ref(),
        )
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "strategy={:<18} rounds={:<3} tokens={:<6} throughput={:>9.1} tok/s  \
             mean latency={}  p95={}  ffn wall={}  slot imbalance={:.3}  \
             busy imbalance={:.3}  dup transfer={} (hidden {} / exposed {})  \
             tile reuse={}/{}  spec slots={}/{}  evictions={} refetch={} \
             resident hwm={}",
            self.strategy,
            self.rounds.len(),
            self.total_tokens(),
            self.throughput(),
            crate::util::human_time(self.mean_round_latency_s()),
            crate::util::human_time(self.p95_round_latency_s()),
            crate::util::human_time(self.mean_ffn_wall_s()),
            self.mean_slot_imbalance(),
            self.mean_busy_imbalance(),
            crate::util::human_bytes(self.total_upload_bytes() as f64),
            crate::util::human_bytes(self.total_hidden_upload_bytes() as f64),
            crate::util::human_bytes(self.total_exposed_upload_bytes() as f64),
            self.total_tile_reuses(),
            self.total_tile_allocs() + self.total_tile_reuses(),
            self.total_spec_dispatch_slots(),
            self.total_spec_dispatch_slots() + self.total_spec_repair_slots(),
            self.total_evictions(),
            crate::util::human_bytes(self.total_refetch_upload_bytes() as f64),
            crate::util::human_bytes(self.resident_high_water_bytes() as f64),
        );
        s.push_str(&self.copy_stats().summary_suffix());
        if self.rounds.iter().any(|r| r.wavefront_window_s > 0.0) {
            s.push_str(&self.wavefront_stats().summary_suffix());
        }
        if let Some(hit) = self.realized_topk_hit_rate() {
            s.push_str(&format!("  pred top-k hit={:.3}", hit));
        }
        if let Some(l1) = self.mean_pred_share_l1() {
            s.push_str(&format!("  share L1={:.3}", l1));
        }
        if let Some(l1) = self.mean_forecast_l1() {
            s.push_str(&format!("  forecast L1={:.3}", l1));
        }
        if let Some(c) = &self.controller {
            s.push_str(&format!(
                "  adaptive: {} decisions / {} switches -> {}",
                c.decisions.len(),
                c.switch_count(),
                c.final_strategy
            ));
        }
        s.push_str(&self.fault_summary().summary_suffix());
        s.push_str(&self.meta.runtime_suffix());
        s
    }
}

/// Metrics for one continuous-batching decode step (prefill rows of newly
/// admitted sequences + one decode row per active sequence).
#[derive(Clone, Debug, Default)]
pub struct DecodeStepMetrics {
    pub step: usize,
    pub n_seqs: usize,
    /// Prompt tokens processed this step (admitted sequences' prefill).
    pub n_prefill_tokens: usize,
    /// Decode rows this step (= sequences past prefill).
    pub n_decode_tokens: usize,
    pub n_slots: usize,
    pub embed_s: f64,
    pub predictor_s: f64,
    pub attention_s: f64,
    pub router_s: f64,
    pub ffn_wall_s: f64,
    pub lm_head_s: f64,
    pub total_s: f64,
    pub worker_busy_s: Vec<f64>,
    pub worker_slots: Vec<usize>,
    /// Total duplication-transfer bytes (= hidden + exposed).
    pub upload_bytes: u64,
    /// Transfer bytes overlapped by the lookahead prewarm (ADR 002).
    pub hidden_upload_bytes: u64,
    /// Transfer bytes on the critical path (blocked-on prewarms + cold
    /// uploads inside `WorkerMsg::RunBatch`).
    pub exposed_upload_bytes: u64,
    /// Worker seconds spent on overlapped transfers.
    pub hidden_transfer_s: f64,
    /// Leader wall seconds stalled waiting on transfers.
    pub exposed_transfer_s: f64,
    pub replicas_added: usize,
    pub routing_skew: f64,
    /// Whether the duplication plan was rebuilt this step (replan cadence).
    pub replanned: bool,
    /// Tile buffers freshly allocated on the FFN dispatch path (ADR 003).
    pub tile_allocs: u64,
    /// Tile buffers recycled from the coordinator's tile pool.
    pub tile_reuses: u64,
    /// Slots dispatched speculatively (predicted expert confirmed
    /// anywhere in the routed top-k — ADR 003/004).
    pub spec_dispatch_slots: usize,
    /// Slots that took the repair pass.
    pub spec_repair_slots: usize,
    /// Replica weights evicted by the residency LRU (ADR 004).
    pub evictions: u64,
    /// Bytes re-uploaded for replicas the cap had evicted.
    pub refetch_upload_bytes: u64,
    /// Peak per-worker resident replica bytes.
    pub resident_high_water_bytes: u64,
    /// Routed slots that carried a per-token prediction (ADR 005).
    pub pred_slots: usize,
    /// Tokens that carried a prediction (top-1 denominator).
    pub pred_tokens: usize,
    /// Slots whose routed expert appeared in the predicted top-k set.
    pub pred_topk_hits: usize,
    /// Tokens whose routed expert set contained the predictor argmax.
    pub pred_top1_hits: usize,
    /// Mean per-layer L1 error between predicted and routed shares.
    pub pred_share_l1: f64,
    /// Layers that carried predicted counts this step.
    pub pred_share_layers: usize,
    /// Mean realized forecast L1 error over forecasts that matured this
    /// step (ADR 006 — see [`RoundMetrics::forecast_l1`]).
    pub forecast_l1: f64,
    /// (layer, forecast) pairs that matured and were scored this step.
    pub forecast_layers: usize,
    /// Workers newly detected dead this step (ADR 008).
    pub worker_deaths: u64,
    /// Slots re-sent to a surviving replica after their owner died or
    /// their reply was lost.
    pub redispatched_slots: usize,
    /// Reply-deadline timeouts waited through (straggler retries).
    pub retry_count: u64,
    /// Prewarm acks abandoned (deadline exhausted or owner died).
    pub prewarm_timeouts: u64,
    /// Sequences evicted back to the waiting queue (requeued, not lost).
    pub requeued_seqs: usize,
    /// The step ran on a degraded fleet (see [`RoundMetrics::degraded`]).
    pub degraded: bool,
    /// Host bytes deep-copied on the data plane (ADR 009 — see
    /// [`RoundMetrics::bytes_copied`]).
    pub bytes_copied: u64,
    /// Host bytes moved by `Arc` reference instead of copied (ADR 009).
    pub bytes_shared: u64,
    /// Coalesced `WorkerMsg::RunBatch` messages sent this step.
    pub ffn_messages: u64,
    /// Leader wall seconds blocked waiting for FFN replies (ADR 010 —
    /// see [`RoundMetrics::leader_stall_s`]).
    pub leader_stall_s: f64,
    /// Wall seconds covered by the per-layer router→combine windows.
    pub wavefront_window_s: f64,
    /// Worker idle fraction inside the wavefront windows (ADR 010).
    pub worker_idle_frac: f64,
    /// Peak FFN slabs checked out of the tile pool at once (ADR 010).
    pub tile_peak: u64,
}

impl DecodeStepMetrics {
    pub fn busy_imbalance(&self) -> f64 {
        let mean = stats::mean(&self.worker_busy_s);
        if mean <= 0.0 {
            return 1.0;
        }
        self.worker_busy_s.iter().cloned().fold(0.0, f64::max) / mean
    }

    pub fn slot_imbalance(&self) -> f64 {
        stats::skewness_of_counts(&self.worker_slots)
    }

    /// A step is steady-state when it carries no prefill work.
    pub fn is_steady_state(&self) -> bool {
        self.n_prefill_tokens == 0 && self.n_decode_tokens > 0
    }
}

/// Aggregate over a continuous-batching decode run.
#[derive(Clone, Debug, Default)]
pub struct DecodeReport {
    pub strategy: String,
    pub steps: Vec<DecodeStepMetrics>,
    /// Decision trace + calibrated snapshots when `--adaptive` drove the
    /// run (ADR 005).
    pub controller: Option<ControllerReport>,
    pub meta: ReportMeta,
    /// Admitted sequences unaccounted for at the end of the run (ADR
    /// 008): admitted ∖ (finished ∪ waiting ∪ active) over unique ids.
    /// The chaos gate requires 0 — every sequence finishes or is
    /// explicitly requeued, never silently dropped.
    pub lost_seqs: u64,
}

impl DecodeReport {
    pub fn total_decode_tokens(&self) -> usize {
        self.steps.iter().map(|s| s.n_decode_tokens).sum()
    }

    pub fn total_prefill_tokens(&self) -> usize {
        self.steps.iter().map(|s| s.n_prefill_tokens).sum()
    }

    pub fn total_s(&self) -> f64 {
        self.steps.iter().map(|s| s.total_s).sum()
    }

    /// Decoded tokens per second over the whole run (prefill included in
    /// the denominator — the serving-level number).
    pub fn decode_tokens_per_s(&self) -> f64 {
        let t = self.total_s();
        if t <= 0.0 {
            0.0
        } else {
            self.total_decode_tokens() as f64 / t
        }
    }

    /// Steady-state throughput: decode tokens per second over the steps
    /// that carried no prefill work (the number `benches/decode_serve.rs`
    /// reports — what the system sustains once admission settles).
    pub fn steady_state_tokens_per_s(&self) -> f64 {
        let (mut tokens, mut time) = (0usize, 0.0f64);
        for s in self.steps.iter().filter(|s| s.is_steady_state()) {
            tokens += s.n_decode_tokens;
            time += s.total_s;
        }
        if time <= 0.0 {
            0.0
        } else {
            tokens as f64 / time
        }
    }

    pub fn steady_state_steps(&self) -> usize {
        self.steps.iter().filter(|s| s.is_steady_state()).count()
    }

    pub fn mean_step_latency_s(&self) -> f64 {
        let xs: Vec<f64> = self.steps.iter().map(|s| s.total_s).collect();
        stats::mean(&xs)
    }

    pub fn p95_step_latency_s(&self) -> f64 {
        let xs: Vec<f64> = self.steps.iter().map(|s| s.total_s).collect();
        stats::percentile(&xs, 95.0)
    }

    pub fn mean_slot_imbalance(&self) -> f64 {
        let xs: Vec<f64> = self.steps.iter().map(|s| s.slot_imbalance()).collect();
        stats::mean(&xs)
    }

    pub fn total_upload_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.upload_bytes).sum()
    }

    pub fn total_hidden_upload_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.hidden_upload_bytes).sum()
    }

    pub fn total_exposed_upload_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.exposed_upload_bytes).sum()
    }

    pub fn total_hidden_transfer_s(&self) -> f64 {
        self.steps.iter().map(|s| s.hidden_transfer_s).sum()
    }

    pub fn total_exposed_transfer_s(&self) -> f64 {
        self.steps.iter().map(|s| s.exposed_transfer_s).sum()
    }

    pub fn total_tile_allocs(&self) -> u64 {
        self.steps.iter().map(|s| s.tile_allocs).sum()
    }

    pub fn total_tile_reuses(&self) -> u64 {
        self.steps.iter().map(|s| s.tile_reuses).sum()
    }

    pub fn total_spec_dispatch_slots(&self) -> usize {
        self.steps.iter().map(|s| s.spec_dispatch_slots).sum()
    }

    pub fn total_spec_repair_slots(&self) -> usize {
        self.steps.iter().map(|s| s.spec_repair_slots).sum()
    }

    pub fn total_evictions(&self) -> u64 {
        self.steps.iter().map(|s| s.evictions).sum()
    }

    pub fn total_refetch_upload_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.refetch_upload_bytes).sum()
    }

    /// Peak per-worker resident replica bytes across the whole run.
    pub fn resident_high_water_bytes(&self) -> u64 {
        self.steps
            .iter()
            .map(|s| s.resident_high_water_bytes)
            .max()
            .unwrap_or(0)
    }

    pub fn replan_count(&self) -> usize {
        self.steps.iter().filter(|s| s.replanned).count()
    }

    pub fn total_pred_slots(&self) -> usize {
        self.steps.iter().map(|s| s.pred_slots).sum()
    }

    /// Realized top-k set hit rate over the run (see [`ServeReport`]).
    pub fn realized_topk_hit_rate(&self) -> Option<f64> {
        let slots = self.total_pred_slots();
        if slots == 0 {
            return None;
        }
        let hits: usize = self.steps.iter().map(|s| s.pred_topk_hits).sum();
        Some(hits as f64 / slots as f64)
    }

    /// Realized argmax accuracy over the run (per token — see
    /// [`ServeReport::realized_top1_rate`]).
    pub fn realized_top1_rate(&self) -> Option<f64> {
        let tokens: usize = self.steps.iter().map(|s| s.pred_tokens).sum();
        if tokens == 0 {
            return None;
        }
        let hits: usize = self.steps.iter().map(|s| s.pred_top1_hits).sum();
        Some(hits as f64 / tokens as f64)
    }

    /// Mean predicted-vs-routed share L1 across steps that carried
    /// predicted counts.
    pub fn mean_pred_share_l1(&self) -> Option<f64> {
        let xs: Vec<f64> = self
            .steps
            .iter()
            .filter(|s| s.pred_share_layers > 0)
            .map(|s| s.pred_share_l1)
            .collect();
        if xs.is_empty() {
            None
        } else {
            Some(stats::mean(&xs))
        }
    }

    pub fn mean_routing_skew(&self) -> f64 {
        let xs: Vec<f64> = self.steps.iter().map(|s| s.routing_skew).collect();
        stats::mean(&xs)
    }

    /// Mean realized forecast L1 error across steps where a horizon
    /// forecast matured (see [`ServeReport::mean_forecast_l1`]).
    pub fn mean_forecast_l1(&self) -> Option<f64> {
        mean_forecast_l1(self.steps.iter().map(|s| (s.forecast_l1, s.forecast_layers)))
    }

    /// Run-level robustness aggregates (ADR 008).
    pub fn fault_summary(&self) -> FaultSummary {
        FaultSummary {
            worker_deaths: self.steps.iter().map(|s| s.worker_deaths).sum(),
            redispatched_slots: self.steps.iter().map(|s| s.redispatched_slots).sum(),
            retries: self.steps.iter().map(|s| s.retry_count).sum(),
            prewarm_timeouts: self.steps.iter().map(|s| s.prewarm_timeouts).sum(),
            requeued_seqs: self.steps.iter().map(|s| s.requeued_seqs).sum(),
            degraded_samples: self.steps.iter().filter(|s| s.degraded).count(),
            lost_seqs: self.lost_seqs,
        }
    }

    /// Run-level data-plane copy accounting (ADR 009).
    pub fn copy_stats(&self) -> CopyStats {
        CopyStats {
            bytes_copied: self.steps.iter().map(|s| s.bytes_copied).sum(),
            bytes_shared: self.steps.iter().map(|s| s.bytes_shared).sum(),
            ffn_messages: self.steps.iter().map(|s| s.ffn_messages).sum(),
        }
    }

    /// Run-level wavefront overlap accounting (ADR 010).
    pub fn wavefront_stats(&self) -> WavefrontStats {
        wavefront_stats(self.steps.iter().map(|s| {
            (
                s.worker_idle_frac,
                s.wavefront_window_s,
                s.leader_stall_s,
                s.tile_peak,
            )
        }))
    }

    /// Serialize to the `moe-gps/serve-report/v1` schema (see
    /// [`ServeReport::to_json`]).
    pub fn to_json(&self) -> Value {
        let samples: Vec<WindowSample> = self.steps.iter().map(WindowSample::from).collect();
        report_json(
            &self.meta,
            &self.strategy,
            self.decode_tokens_per_s(),
            self.total_decode_tokens(),
            self.mean_forecast_l1(),
            &self.fault_summary(),
            &self.copy_stats(),
            &self.wavefront_stats(),
            &samples,
            self.controller.as_ref(),
        )
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "strategy={:<18} steps={:<4} decoded={:<6} throughput={:>8.1} tok/s  \
             steady={:>8.1} tok/s ({} steps)  mean step={}  p95={}  \
             slot imbalance={:.3}  replans={}  dup transfer={} \
             (hidden {} / exposed {})  tile reuse={}/{}  spec slots={}/{}  \
             evictions={} refetch={} resident hwm={}",
            self.strategy,
            self.steps.len(),
            self.total_decode_tokens(),
            self.decode_tokens_per_s(),
            self.steady_state_tokens_per_s(),
            self.steady_state_steps(),
            crate::util::human_time(self.mean_step_latency_s()),
            crate::util::human_time(self.p95_step_latency_s()),
            self.mean_slot_imbalance(),
            self.replan_count(),
            crate::util::human_bytes(self.total_upload_bytes() as f64),
            crate::util::human_bytes(self.total_hidden_upload_bytes() as f64),
            crate::util::human_bytes(self.total_exposed_upload_bytes() as f64),
            self.total_tile_reuses(),
            self.total_tile_allocs() + self.total_tile_reuses(),
            self.total_spec_dispatch_slots(),
            self.total_spec_dispatch_slots() + self.total_spec_repair_slots(),
            self.total_evictions(),
            crate::util::human_bytes(self.total_refetch_upload_bytes() as f64),
            crate::util::human_bytes(self.resident_high_water_bytes() as f64),
        );
        s.push_str(&self.copy_stats().summary_suffix());
        if self.steps.iter().any(|st| st.wavefront_window_s > 0.0) {
            s.push_str(&self.wavefront_stats().summary_suffix());
        }
        if let Some(hit) = self.realized_topk_hit_rate() {
            s.push_str(&format!("  pred top-k hit={:.3}", hit));
        }
        if let Some(l1) = self.mean_pred_share_l1() {
            s.push_str(&format!("  share L1={:.3}", l1));
        }
        if let Some(l1) = self.mean_forecast_l1() {
            s.push_str(&format!("  forecast L1={:.3}", l1));
        }
        if let Some(c) = &self.controller {
            s.push_str(&format!(
                "  adaptive: {} decisions / {} switches -> {}",
                c.decisions.len(),
                c.switch_count(),
                c.final_strategy
            ));
        }
        s.push_str(&self.fault_summary().summary_suffix());
        s.push_str(&self.meta.runtime_suffix());
        s
    }
}

/// Layer-weighted mean of per-round/step realized forecast L1s (`None`
/// when no forecast matured anywhere in the run — e.g. horizon 0).
fn mean_forecast_l1(per_window: impl Iterator<Item = (f64, usize)>) -> Option<f64> {
    let (mut sum, mut n) = (0.0f64, 0usize);
    for (l1, layers) in per_window {
        if layers > 0 {
            sum += l1 * layers as f64;
            n += layers;
        }
    }
    if n == 0 {
        None
    } else {
        Some(sum / n as f64)
    }
}

/// Assemble the serve-report JSON shared by both report kinds: the
/// rolling-window calibrator is replayed over the run's samples to fit
/// the measured constants, and the first-half-fit / second-half-holdout
/// check quantifies how well the fitted cost model predicts throughput it
/// did not see (the CI drift gate's number).
#[allow(clippy::too_many_arguments)]
fn report_json(
    meta: &ReportMeta,
    strategy: &str,
    tokens_per_s: f64,
    tokens: usize,
    forecast_l1: Option<f64>,
    faults: &FaultSummary,
    copy: &CopyStats,
    wavefront: &WavefrontStats,
    samples: &[WindowSample],
    controller: Option<&ControllerReport>,
) -> Value {
    let mut cal = OnlineCalibrator::new(samples.len().max(1));
    for s in samples {
        cal.push(s.clone());
    }
    let mut root = Value::obj();
    root.set("schema", Value::Str(REPORT_SCHEMA.into()))
        .set("meta", meta.to_json())
        .set("strategy", Value::Str(strategy.into()))
        .set("tokens", Value::Num(tokens as f64))
        .set("tokens_per_s", Value::Num(tokens_per_s))
        .set(
            "forecast_l1",
            match forecast_l1 {
                Some(l1) => Value::Num(l1),
                None => Value::Null,
            },
        )
        // Robustness aggregates (ADR 008): root-level additive keys, all
        // zero on healthy runs; pre-ADR-008 readers ignore them.
        .set("worker_deaths", Value::Num(faults.worker_deaths as f64))
        .set(
            "redispatched_slots",
            Value::Num(faults.redispatched_slots as f64),
        )
        .set("retries", Value::Num(faults.retries as f64))
        .set(
            "prewarm_timeouts",
            Value::Num(faults.prewarm_timeouts as f64),
        )
        .set("requeued_seqs", Value::Num(faults.requeued_seqs as f64))
        .set(
            "degraded_samples",
            Value::Num(faults.degraded_samples as f64),
        )
        .set("lost_seqs", Value::Num(faults.lost_seqs as f64))
        // Data-plane copy accounting (ADR 009): root-level additive keys
        // the sim's transfer pricing, `advise --from-serve`, and the
        // `bench-validate --max-copied-frac` gate read.
        .set("bytes_copied", Value::Num(copy.bytes_copied as f64))
        .set("bytes_shared", Value::Num(copy.bytes_shared as f64))
        .set("ffn_messages", Value::Num(copy.ffn_messages as f64))
        // Wavefront overlap accounting (ADR 010): root-level additive
        // keys the `bench-validate --max-idle-frac` gate reads.
        .set("worker_idle_frac", Value::Num(wavefront.worker_idle_frac))
        .set("leader_stall_s", Value::Num(wavefront.leader_stall_s))
        .set("tile_peak", Value::Num(wavefront.tile_peak as f64))
        .set(
            "measured",
            match cal.constants() {
                Some(c) => c.to_json(),
                None => Value::Null,
            },
        )
        .set(
            "calibration_check",
            match calibration_check(samples) {
                Some(c) => c.to_json(),
                None => Value::Null,
            },
        )
        .set(
            "controller",
            match controller {
                Some(c) => c.to_json(),
                None => Value::Null,
            },
        )
        .set(
            "samples",
            Value::Arr(samples.iter().map(WindowSample::to_json).collect()),
        );
    root
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imbalance_math() {
        let m = RoundMetrics {
            worker_busy_s: vec![2.0, 1.0, 1.0, 0.0],
            worker_slots: vec![100, 50, 50, 0],
            n_tokens: 200,
            total_s: 0.5,
            ..Default::default()
        };
        assert!((m.busy_imbalance() - 2.0).abs() < 1e-9);
        assert!((m.slot_imbalance() - 2.0).abs() < 1e-9);
        assert!((m.tokens_per_s() - 400.0).abs() < 1e-9);
    }

    #[test]
    fn report_aggregates() {
        let mut rep = ServeReport {
            strategy: "test".into(),
            rounds: Vec::new(),
            ..Default::default()
        };
        for i in 1..=4 {
            rep.rounds.push(RoundMetrics {
                n_tokens: 100 * i,
                total_s: 0.1,
                worker_busy_s: vec![1.0; 4],
                worker_slots: vec![25; 4],
                ..Default::default()
            });
        }
        assert_eq!(rep.total_tokens(), 1000);
        assert!((rep.throughput() - 2500.0).abs() < 1e-9);
        assert!((rep.mean_busy_imbalance() - 1.0).abs() < 1e-9);
        assert!(rep.summary().contains("tok/s"));
    }

    #[test]
    fn decode_report_steady_state_excludes_prefill_steps() {
        let mut rep = DecodeReport {
            strategy: "test".into(),
            steps: Vec::new(),
            ..Default::default()
        };
        // Step 0: mixed prefill + decode; steps 1-2: pure decode.
        rep.steps.push(DecodeStepMetrics {
            step: 0,
            n_prefill_tokens: 32,
            n_decode_tokens: 4,
            total_s: 1.0,
            ..Default::default()
        });
        for step in 1..3 {
            rep.steps.push(DecodeStepMetrics {
                step,
                n_decode_tokens: 4,
                total_s: 0.1,
                ..Default::default()
            });
        }
        assert_eq!(rep.total_decode_tokens(), 12);
        assert_eq!(rep.steady_state_steps(), 2);
        assert!((rep.steady_state_tokens_per_s() - 40.0).abs() < 1e-9);
        assert!((rep.decode_tokens_per_s() - 10.0).abs() < 1e-9);
        assert!(rep.summary().contains("steady"));
    }

    #[test]
    fn hidden_and_exposed_transfer_aggregate() {
        let mut rep = DecodeReport {
            strategy: "test".into(),
            steps: Vec::new(),
            ..Default::default()
        };
        for step in 0..2 {
            rep.steps.push(DecodeStepMetrics {
                step,
                upload_bytes: 100,
                hidden_upload_bytes: 60,
                exposed_upload_bytes: 40,
                hidden_transfer_s: 0.5,
                exposed_transfer_s: 0.25,
                ..Default::default()
            });
        }
        assert_eq!(rep.total_upload_bytes(), 200);
        assert_eq!(rep.total_hidden_upload_bytes(), 120);
        assert_eq!(rep.total_exposed_upload_bytes(), 80);
        assert!((rep.total_hidden_transfer_s() - 1.0).abs() < 1e-12);
        assert!((rep.total_exposed_transfer_s() - 0.5).abs() < 1e-12);
        assert!(rep.summary().contains("hidden"));

        let round = RoundMetrics {
            upload_bytes: 10,
            hidden_upload_bytes: 10,
            ..Default::default()
        };
        let serve = ServeReport {
            strategy: "test".into(),
            rounds: vec![round],
            ..Default::default()
        };
        assert_eq!(serve.total_hidden_upload_bytes(), 10);
        assert_eq!(serve.total_exposed_upload_bytes(), 0);
        assert!(serve.summary().contains("hidden"));
    }

    #[test]
    fn tile_and_spec_counters_aggregate() {
        let serve = ServeReport {
            strategy: "test".into(),
            rounds: vec![
                RoundMetrics {
                    tile_allocs: 5,
                    tile_reuses: 0,
                    spec_dispatch_slots: 3,
                    spec_repair_slots: 7,
                    ..Default::default()
                },
                RoundMetrics {
                    tile_allocs: 0,
                    tile_reuses: 9,
                    spec_dispatch_slots: 4,
                    spec_repair_slots: 6,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        assert_eq!(serve.total_tile_allocs(), 5);
        assert_eq!(serve.total_tile_reuses(), 9);
        assert_eq!(serve.total_spec_dispatch_slots(), 7);
        assert_eq!(serve.total_spec_repair_slots(), 13);
        assert!(serve.summary().contains("tile reuse=9/14"));
        assert!(serve.summary().contains("spec slots=7/20"));

        let decode = DecodeReport {
            strategy: "test".into(),
            steps: vec![DecodeStepMetrics {
                tile_allocs: 2,
                tile_reuses: 8,
                spec_dispatch_slots: 1,
                spec_repair_slots: 1,
                ..Default::default()
            }],
            ..Default::default()
        };
        assert_eq!(decode.total_tile_allocs(), 2);
        assert_eq!(decode.total_tile_reuses(), 8);
        assert_eq!(decode.total_spec_dispatch_slots(), 1);
        assert_eq!(decode.total_spec_repair_slots(), 1);
        assert!(decode.summary().contains("tile reuse=8/10"));
    }

    #[test]
    fn forecast_l1_aggregates_layer_weighted_and_skips_empty_windows() {
        let serve = ServeReport {
            strategy: "test".into(),
            rounds: vec![
                // Horizon-0 round: no forecast matured — must not drag the
                // mean toward zero.
                RoundMetrics::default(),
                RoundMetrics {
                    forecast_l1: 0.2,
                    forecast_layers: 1,
                    ..Default::default()
                },
                RoundMetrics {
                    forecast_l1: 0.5,
                    forecast_layers: 3,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        // (0.2·1 + 0.5·3) / 4 = 0.425
        let l1 = serve.mean_forecast_l1().expect("forecasts matured");
        assert!((l1 - 0.425).abs() < 1e-12);
        assert!(serve.summary().contains("forecast L1=0.425"));

        let reactive = ServeReport {
            strategy: "test".into(),
            rounds: vec![RoundMetrics::default()],
            ..Default::default()
        };
        assert!(reactive.mean_forecast_l1().is_none());
        assert!(!reactive.summary().contains("forecast L1"));

        let decode = DecodeReport {
            strategy: "test".into(),
            steps: vec![DecodeStepMetrics {
                forecast_l1: 0.1,
                forecast_layers: 2,
                ..Default::default()
            }],
            ..Default::default()
        };
        assert!((decode.mean_forecast_l1().unwrap() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn residency_counters_aggregate_and_peak() {
        // Evictions and refetch bytes are flows (summed); the resident
        // high-water mark is a peak (max over rounds/steps) — ADR 004.
        let serve = ServeReport {
            strategy: "test".into(),
            rounds: vec![
                RoundMetrics {
                    evictions: 2,
                    refetch_upload_bytes: 100,
                    resident_high_water_bytes: 700,
                    ..Default::default()
                },
                RoundMetrics {
                    evictions: 3,
                    refetch_upload_bytes: 50,
                    resident_high_water_bytes: 400,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        assert_eq!(serve.total_evictions(), 5);
        assert_eq!(serve.total_refetch_upload_bytes(), 150);
        assert_eq!(serve.resident_high_water_bytes(), 700);
        assert!(serve.summary().contains("evictions=5"));
        assert!(serve.summary().contains("resident hwm="));

        let decode = DecodeReport {
            strategy: "test".into(),
            steps: vec![
                DecodeStepMetrics {
                    evictions: 1,
                    refetch_upload_bytes: 10,
                    resident_high_water_bytes: 300,
                    ..Default::default()
                },
                DecodeStepMetrics {
                    evictions: 0,
                    refetch_upload_bytes: 0,
                    resident_high_water_bytes: 350,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        assert_eq!(decode.total_evictions(), 1);
        assert_eq!(decode.total_refetch_upload_bytes(), 10);
        assert_eq!(decode.resident_high_water_bytes(), 350);
        assert!(decode.summary().contains("evictions=1"));
    }

    #[test]
    fn fault_summary_aggregates_and_gates_the_summary_line() {
        // Healthy run: no fault aggregates, no fault line in the summary,
        // but the JSON still carries the zeroed root keys (additive
        // schema — ADR 008).
        let healthy = DecodeReport {
            strategy: "test".into(),
            steps: vec![DecodeStepMetrics::default()],
            ..Default::default()
        };
        assert!(!healthy.fault_summary().any());
        assert!(!healthy.summary().contains("faults:"));
        let json = healthy.to_json().to_string_compact();
        assert!(json.contains("\"worker_deaths\""));
        assert!(json.contains("\"lost_seqs\""));

        let degraded = DecodeReport {
            strategy: "test".into(),
            steps: vec![
                DecodeStepMetrics {
                    worker_deaths: 1,
                    redispatched_slots: 12,
                    retry_count: 3,
                    prewarm_timeouts: 2,
                    requeued_seqs: 1,
                    degraded: true,
                    ..Default::default()
                },
                DecodeStepMetrics {
                    degraded: true,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        let f = degraded.fault_summary();
        assert_eq!(f.worker_deaths, 1);
        assert_eq!(f.redispatched_slots, 12);
        assert_eq!(f.retries, 3);
        assert_eq!(f.prewarm_timeouts, 2);
        assert_eq!(f.requeued_seqs, 1);
        assert_eq!(f.degraded_samples, 2);
        assert_eq!(f.lost_seqs, 0);
        let s = degraded.summary();
        assert!(s.contains("faults: deaths=1"));
        assert!(s.contains("degraded windows=2"));
        assert!(s.contains("lost=0"));

        let serve = ServeReport {
            strategy: "test".into(),
            rounds: vec![RoundMetrics {
                worker_deaths: 2,
                degraded: true,
                ..Default::default()
            }],
            ..Default::default()
        };
        assert_eq!(serve.fault_summary().worker_deaths, 2);
        assert_eq!(serve.fault_summary().degraded_samples, 1);
        assert!(serve.summary().contains("faults: deaths=2"));
    }

    #[test]
    fn copy_stats_aggregate_and_reach_the_report_json() {
        // ADR 009: bytes_copied / bytes_shared / ffn_messages sum over
        // rounds (steps), surface in the summary line, and land as
        // root-level keys of the serve-report JSON.
        let serve = ServeReport {
            strategy: "test".into(),
            rounds: vec![
                RoundMetrics {
                    bytes_copied: 256,
                    bytes_shared: 768,
                    ffn_messages: 4,
                    ..Default::default()
                },
                RoundMetrics {
                    bytes_copied: 0,
                    bytes_shared: 1024,
                    ffn_messages: 2,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        let c = serve.copy_stats();
        assert_eq!(c.bytes_copied, 256);
        assert_eq!(c.bytes_shared, 1792);
        assert_eq!(c.ffn_messages, 6);
        assert!((c.copied_frac() - 256.0 / 2048.0).abs() < 1e-12);
        assert!(serve.summary().contains("ffn msgs=6"));
        let json = serve.to_json().to_string_compact();
        assert!(json.contains("\"bytes_copied\""));
        assert!(json.contains("\"bytes_shared\""));
        assert!(json.contains("\"ffn_messages\""));

        // An idle plane divides to zero, not NaN.
        assert_eq!(CopyStats::default().copied_frac(), 0.0);

        let decode = DecodeReport {
            strategy: "test".into(),
            steps: vec![DecodeStepMetrics {
                bytes_copied: 64,
                bytes_shared: 192,
                ffn_messages: 3,
                ..Default::default()
            }],
            ..Default::default()
        };
        assert_eq!(decode.copy_stats().bytes_copied, 64);
        assert!((decode.copy_stats().copied_frac() - 0.25).abs() < 1e-12);
        assert!(decode.summary().contains("ffn msgs=3"));
    }

    #[test]
    fn wavefront_stats_aggregate_and_reach_the_report_json() {
        // ADR 010: idle fraction is window-weighted, leader stall sums,
        // tile peak is a max — and all three land as root-level JSON keys.
        let serve = ServeReport {
            strategy: "test".into(),
            rounds: vec![
                RoundMetrics {
                    worker_idle_frac: 0.5,
                    wavefront_window_s: 1.0,
                    leader_stall_s: 0.2,
                    tile_peak: 4,
                    ..Default::default()
                },
                RoundMetrics {
                    worker_idle_frac: 0.2,
                    wavefront_window_s: 3.0,
                    leader_stall_s: 0.1,
                    tile_peak: 7,
                    ..Default::default()
                },
                // A round with no recorded window must not dilute the mean.
                RoundMetrics::default(),
            ],
            ..Default::default()
        };
        let w = serve.wavefront_stats();
        // (0.5·1 + 0.2·3) / 4 = 0.275
        assert!((w.worker_idle_frac - 0.275).abs() < 1e-12);
        assert!((w.leader_stall_s - 0.3).abs() < 1e-12);
        assert_eq!(w.tile_peak, 7);
        assert!(serve.summary().contains("idle frac=0.275"));
        assert!(serve.summary().contains("tile peak=7"));
        let json = serve.to_json().to_string_compact();
        assert!(json.contains("\"worker_idle_frac\""));
        assert!(json.contains("\"leader_stall_s\""));
        assert!(json.contains("\"tile_peak\""));

        // A run that never recorded a window reports zeros and keeps the
        // summary line clean, but the JSON keys are still present
        // (additive schema — the gate fails loudly only on pre-ADR-010
        // reports that lack the keys entirely).
        let serial = ServeReport {
            strategy: "test".into(),
            rounds: vec![RoundMetrics::default()],
            ..Default::default()
        };
        assert_eq!(serial.wavefront_stats(), WavefrontStats::default());
        assert!(!serial.summary().contains("idle frac"));
        assert!(serial.to_json().to_string_compact().contains("\"worker_idle_frac\""));

        let decode = DecodeReport {
            strategy: "test".into(),
            steps: vec![DecodeStepMetrics {
                worker_idle_frac: 0.4,
                wavefront_window_s: 2.0,
                leader_stall_s: 0.05,
                tile_peak: 3,
                ..Default::default()
            }],
            ..Default::default()
        };
        assert!((decode.wavefront_stats().worker_idle_frac - 0.4).abs() < 1e-12);
        assert_eq!(decode.wavefront_stats().tile_peak, 3);
        assert!(decode.summary().contains("idle frac=0.400"));
    }

    #[test]
    fn report_meta_microbatch_reaches_the_json() {
        let meta = ReportMeta {
            microbatch: 4,
            ..Default::default()
        };
        let json = meta.to_json().to_string_compact();
        assert!(json.contains("\"microbatch\":4"));
    }
}
