//! Serving requests and synthetic request generation.

use std::time::Instant;

use crate::util::rng::Rng;

/// One serving request: a prompt token sequence, plus (for the decode
/// phase) a generation budget. `max_new_tokens == 0` means prefill-only —
/// under continuous batching such a request finishes right after its first
/// sampled token.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub arrival: Instant,
    /// Tokens to generate after the prompt (decode-phase budget).
    pub max_new_tokens: usize,
}

impl Request {
    pub fn new(id: u64, tokens: Vec<u32>) -> Request {
        Request {
            id,
            tokens,
            arrival: Instant::now(),
            max_new_tokens: 0,
        }
    }

    /// Builder-style decode budget.
    pub fn with_max_new_tokens(mut self, n: usize) -> Request {
        self.max_new_tokens = n;
        self
    }
}

/// Synthetic request generator: token ids drawn from a Zipf-ish
/// distribution (natural-language-like reuse of frequent tokens, which is
/// what gives conditional/neural predictors something to learn).
pub struct RequestGen {
    rng: Rng,
    vocab: usize,
    next_id: u64,
    /// Zipf exponent; 0 = uniform.
    pub zipf_s: f64,
}

impl RequestGen {
    pub fn new(seed: u64, vocab: usize) -> RequestGen {
        RequestGen {
            rng: Rng::new(seed),
            vocab,
            next_id: 0,
            zipf_s: 0.8,
        }
    }

    fn sample_token(&mut self) -> u32 {
        if self.zipf_s <= 0.0 {
            return self.rng.below(self.vocab as u64) as u32;
        }
        // Inverse-CDF Zipf approximation via rejection-free power sampling.
        let u = self.rng.f64().max(1e-12);
        let v = self.vocab as f64;
        let rank = (v.powf(1.0 - self.zipf_s) * u + 1.0 - u)
            .powf(1.0 / (1.0 - self.zipf_s))
            .min(v);
        (rank as u32).saturating_sub(1).min(self.vocab as u32 - 1)
    }

    /// Generate a request with the given length.
    pub fn request(&mut self, len: usize) -> Request {
        let tokens = (0..len).map(|_| self.sample_token()).collect();
        let id = self.next_id;
        self.next_id += 1;
        Request::new(id, tokens)
    }

    /// Generate a request with length uniform in [lo, hi].
    pub fn request_varlen(&mut self, lo: usize, hi: usize) -> Request {
        let len = self.rng.range(lo, hi + 1);
        self.request(len)
    }

    /// Generate a decode-phase request: `prompt_len` prompt tokens plus a
    /// generation budget.
    pub fn decode_request(&mut self, prompt_len: usize, max_new_tokens: usize) -> Request {
        self.request(prompt_len).with_max_new_tokens(max_new_tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_in_vocab() {
        let mut g = RequestGen::new(3, 4096);
        for _ in 0..50 {
            let r = g.request_varlen(8, 256);
            assert!(!r.tokens.is_empty() && r.tokens.len() <= 256);
            assert!(r.tokens.iter().all(|&t| (t as usize) < 4096));
        }
    }

    #[test]
    fn ids_are_unique_and_increasing() {
        let mut g = RequestGen::new(4, 100);
        let a = g.request(4);
        let b = g.request(4);
        assert!(b.id > a.id);
    }

    #[test]
    fn zipf_skews_token_frequency() {
        let mut g = RequestGen::new(5, 1000);
        g.zipf_s = 1.1;
        let mut low = 0usize;
        let mut n = 0usize;
        for _ in 0..50 {
            for &t in &g.request(128).tokens {
                n += 1;
                if t < 100 {
                    low += 1;
                }
            }
        }
        // With a Zipf tail, the first 10% of ids get far more than 10%.
        assert!(low as f64 / n as f64 > 0.3, "{low}/{n}");
    }
}
