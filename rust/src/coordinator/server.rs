//! The serving leader: drives prefill rounds and continuous-batching
//! decode steps through the AOT model under Expert Parallelism with
//! predictor-driven dynamic duplication.
//!
//! Both serving phases share the stage-based layer pipeline in
//! [`super::pipeline`] (ADR 002):
//!
//! 1. embed every sequence (leader engine) — whole prompts for prefill
//!    rounds and newly admitted sequences, one row per decoding sequence;
//! 2. *predict + plan* ([`Coordinator::build_plans`]): Token-to-Expert runs
//!    the AOT predictor on the embeddings — before attention, §3.1 —
//!    Distribution-Only converts the online MLE estimators into expected
//!    counts (under the ADR-001 replan cadence in decode), and the baseline
//!    keeps the static placement;
//! 3. per layer ([`Coordinator::run_layers`]): prewarm(L+1) when
//!    `lookahead` is on → attention → fused router + rust top-k →
//!    plan-driven dispatch (quota dispatch for TEP, least-loaded over
//!    replicas for DOP, home GPU for the baseline) → bucket-padded expert
//!    FFN on the virtual-GPU workers → slot-order gate-and-combine →
//!    estimator observe (the §3.2.1 moving average);
//! 4. decode steps finish with `lm_head` + seeded sampling.
//!
//! Decode steps carry one token per decoding sequence plus the full prompt
//! of each newly admitted sequence (continuous batching — admission and
//! eviction are iteration-level, per [`super::scheduler`]); attention runs
//! incrementally over per-sequence KV caches (DESIGN.md §4).

use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use super::controller::{Decision, StrategyController};
use super::faults::{
    is_all_workers_dead, sequence_fault_err, sequence_fault_id, FaultPlan, WorkerHealth,
};
use super::metrics::{
    DecodeReport, DecodeStepMetrics, ReportMeta, RoundMetrics, ServeReport,
};
use super::pipeline::{AttentionMode, StageMetrics};
use super::placement_mgr::PlacementManager;
use super::predict::TepHead;
use super::request::Request;
use super::residency::ResidencyManager;
use super::scheduler::{Scheduler, SeqPhase};
use super::tile_pool::TilePool;
use super::worker::{WorkerHandle, WorkerMsg};
use crate::gps::select::Regime;
use crate::runtime::tensor::IntTensor;
use crate::runtime::{Engine, EngineSource, HostTensor, In};
use crate::util::rng::Rng;

/// Which prediction strategy drives placement (paper §3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeStrategy {
    NoPrediction,
    DistributionOnly,
    TokenToExpert,
}

impl ServeStrategy {
    pub fn name(self) -> &'static str {
        match self {
            ServeStrategy::NoPrediction => "none",
            ServeStrategy::DistributionOnly => "distribution-only",
            ServeStrategy::TokenToExpert => "token-to-expert",
        }
    }

    pub fn by_name(s: &str) -> Result<ServeStrategy> {
        match s {
            "none" | "baseline" => Ok(ServeStrategy::NoPrediction),
            "distribution-only" | "dop" => Ok(ServeStrategy::DistributionOnly),
            "token-to-expert" | "tep" => Ok(ServeStrategy::TokenToExpert),
            other => anyhow::bail!("unknown strategy `{other}`"),
        }
    }
}

/// Model dims read from the artifact manifest.
#[derive(Clone, Debug)]
pub(crate) struct Dims {
    pub(crate) d_model: usize,
    pub(crate) n_experts: usize,
    pub(crate) n_layers: usize,
    pub(crate) top_k: usize,
    pub(crate) seq_len: usize,
    pub(crate) vocab: usize,
}

/// Knobs for a continuous-batching decode run.
#[derive(Clone, Debug)]
pub struct DecodeOptions {
    /// Maximum concurrently active sequences (the continuous batch size).
    pub max_active: usize,
    /// Hard step budget for the run.
    pub max_steps: usize,
    /// Sampling temperature; `<= 0` = greedy argmax.
    pub temperature: f64,
    /// Sampling seed (the run is deterministic given it).
    pub seed: u64,
    /// 0 = all requests arrive up front (pure decode after warmup);
    /// N > 0 = one queued request arrives every N steps (`--phase mixed`).
    pub arrival_interval: usize,
}

impl Default for DecodeOptions {
    fn default() -> Self {
        DecodeOptions {
            max_active: 8,
            max_steps: 512,
            temperature: 1.0,
            seed: 17,
            arrival_interval: 0,
        }
    }
}

/// Per-sequence tensors the decode path keeps across steps.
pub(crate) struct SeqSession {
    /// Prompt plus generated tokens.
    pub(crate) tokens: Vec<u32>,
    /// Per-layer (K, V) caches, `[t, n_kv_heads * head_dim]`.
    pub(crate) kv: Vec<Option<(HostTensor, HostTensor)>>,
}

/// One sequence's share of a decode step.
pub(crate) struct StepSeq {
    pub(crate) id: u64,
    pub(crate) rows: usize,
    pub(crate) prefill: bool,
}

pub struct Coordinator {
    pub(crate) leader: Engine,
    pub(crate) workers: Vec<WorkerHandle>,
    pub placement: PlacementManager,
    pub strategy: ServeStrategy,
    pub(crate) dims: Dims,
    pub(crate) buckets: Vec<usize>,
    pub(crate) round_tag: u64,
    /// Coordinator-side residency: a per-worker capacity-bounded LRU over
    /// (layer, expert) replica weights (ADR 004). Gates lookahead prewarm
    /// sends, emits `WorkerMsg::Evict` under `--memory-cap`, and accounts
    /// evictions / refetches / the resident high-water mark. Crate-private:
    /// every mutation must pair with the matching worker message (admit →
    /// upload, remove → Evict), so external code configures the cap via
    /// [`Coordinator::set_memory_cap`] and reads via
    /// [`Coordinator::residency`].
    pub(crate) residency: ResidencyManager,
    /// §Perf iteration 2: fan per-sequence attention out to the workers
    /// (the TP analogue). Measured neutral on this substrate — the PJRT
    /// CPU client already saturates all cores per execution, so parallel
    /// clients contend; on real multi-device hardware this is the right
    /// topology. Default off (leader attention); kept selectable + tested.
    /// Applies to prefill rounds; decode attention always runs on the
    /// leader (single-row matvecs — a worker round-trip costs more than
    /// the op).
    pub parallel_attention: bool,
    /// §Perf iteration 4 / ADR 002, generalised by ADR 004: overlap the
    /// next `lookahead` layers' prediction, planning and replica prewarm
    /// transfers with the current layer's compute (`serve --lookahead N`).
    /// 0 (the default) disables the prewarm pipeline so both regimes stay
    /// reproducible; numerics are bitwise identical at every depth.
    pub lookahead: usize,
    /// ADR 004: byte budget for prewarm transfers issued per layer step
    /// (`serve --prewarm-budget`). Nearest-layer prewarms fill the budget
    /// first, so the deepest lookahead transfers are the first dropped;
    /// `None` = unbudgeted.
    pub prewarm_budget_bytes: Option<u64>,
    /// §Perf iteration 5 / ADR 003: speculative TEP scatter (`serve
    /// --speculative 1`). Requires `lookahead` and the Token-to-Expert
    /// strategy: slots whose §3.1 prediction the router confirms ship on a
    /// fast path before the repair dispatch runs, and each layer's
    /// speculative targets are derived during the previous layer's FFN
    /// phase. Numerics are bitwise identical either way.
    pub speculative: bool,
    /// ADR 010: micro-batch wavefront depth (`serve --microbatch K`).
    /// K > 1 splits every round/step's sequences into K deterministic
    /// contiguous chunks and pipelines router → dispatch → FFN → combine
    /// across them, so the workers stay busy through the leader's routing
    /// and combine work. 1 (the default) is the serial per-layer barrier
    /// path; outputs are bitwise identical at every K
    /// (`tests/wavefront.rs`).
    pub microbatch: usize,
    /// Reusable tile-buffer arena for the FFN dispatch path (ADR 003):
    /// steady-state serving gathers/pads/scatters with zero per-layer
    /// heap allocation; buffers recycle via the worker reply path.
    pub(crate) tiles: TilePool,
    /// The AOT Token-to-Expert bridge (ADR 005): op/weight names + the
    /// shared logits→top-k conversion (`coordinator::predict`).
    pub(crate) tep: TepHead,
    /// The online strategy controller (`serve --adaptive`, ADR 005):
    /// consulted at replan boundaries, it can switch DOP↔TEP, toggle the
    /// speculative scatter and adjust lookahead depth from measured
    /// metrics. `None` = fixed-strategy serving (the default).
    pub controller: Option<StrategyController>,
    /// ADR 008: per-worker liveness + the cost-model reply deadline. The
    /// pipeline's collect loops consult it to detect dead workers and the
    /// failover path routes around them; crate-private because every
    /// `mark_dead` must pair with residency reclaim + placement re-homing
    /// (see [`Coordinator::note_worker_death`]).
    pub(crate) health: WorkerHealth,
}

impl Coordinator {
    /// Build a coordinator with `n_workers` virtual GPUs over the
    /// artifacts directory, falling back to the synthetic tiny model when
    /// no artifacts exist (so serving works in every build environment).
    pub fn new(
        artifacts_dir: &Path,
        n_workers: usize,
        strategy: ServeStrategy,
    ) -> Result<Coordinator> {
        let source = EngineSource::detect(artifacts_dir);
        if source.is_synthetic() {
            crate::util::logging::log(
                crate::util::logging::Level::Info,
                "coordinator::server",
                format_args!(
                    "no artifacts at {}; serving the synthetic tiny model \
                     (reference backend)",
                    artifacts_dir.display()
                ),
            );
        }
        Coordinator::with_source(&source, n_workers, strategy)
    }

    /// Build a coordinator over an explicit engine source.
    pub fn with_source(
        source: &EngineSource,
        n_workers: usize,
        strategy: ServeStrategy,
    ) -> Result<Coordinator> {
        let mut leader = Engine::from_source(source).context("leader engine")?;
        let cfg = leader.manifest().config.clone();
        let dims = Dims {
            d_model: cfg.req_usize("d_model")?,
            n_experts: cfg.req_usize("n_experts")?,
            n_layers: cfg.req_usize("n_layers")?,
            top_k: cfg.req_usize("top_k")?,
            seq_len: cfg.req_usize("seq_len")?,
            vocab: cfg.req_usize("vocab_size")?,
        };
        let buckets = leader.manifest().ffn_buckets();
        anyhow::ensure!(!buckets.is_empty(), "no expert_ffn buckets in manifest");

        // Pre-compile the leader path.
        for name in ["embed", "attention", "router", "predictor"] {
            leader.load(name)?;
        }

        let workers: Vec<WorkerHandle> = (0..n_workers)
            .map(|i| WorkerHandle::spawn(i, source.clone()))
            .collect::<Result<_>>()?;

        // Bytes of one (layer, expert) replica — the unit the residency
        // LRU budgets and the duplication transfer moves (ADR 004).
        let replica_bytes: u64 = ["w_gate", "w_up", "w_down"]
            .iter()
            .map(|m| {
                leader
                    .weight_store()
                    .nbytes(&format!("layers.0.experts.0.{m}"))
                    .map(|b| b as u64)
            })
            .sum::<Result<u64>>()
            .context("sizing expert replica weights")?;

        // Capacity: up to all experts can fit (CPU memory is not the
        // constraint here); C_max = n_workers mirrors "replicate at most
        // once per GPU".
        let placement = PlacementManager::new(
            dims.n_experts,
            n_workers,
            dims.n_layers,
            dims.n_experts,
            n_workers,
        );

        let tep = TepHead::new(dims.n_layers, dims.n_experts, dims.top_k);
        let mut coord = Coordinator {
            leader,
            workers,
            placement,
            strategy,
            dims,
            buckets,
            round_tag: 0,
            residency: ResidencyManager::new(n_workers, replica_bytes),
            parallel_attention: false,
            lookahead: 0,
            prewarm_budget_bytes: None,
            speculative: false,
            microbatch: 1,
            tiles: TilePool::new(),
            tep,
            controller: None,
            health: WorkerHealth::new(n_workers),
        };
        // `MOE_GPS_FAULTS` injects faults in contexts that don't thread the
        // CLI flag (tests, CI chaos jobs); the flag takes precedence when
        // both are set because `set_fault_plan` re-sends (ADR 008).
        if let Some(plan) = FaultPlan::from_env()? {
            coord.set_fault_plan(&plan);
        }
        Ok(coord)
    }

    /// Install a deterministic fault-injection plan (ADR 008): each
    /// worker receives its own script over the FIFO command queue, so the
    /// faults are in place before any serving op. An empty plan is a
    /// no-op; with injection disabled serving output is bitwise identical
    /// to a build without the fault machinery.
    pub fn set_fault_plan(&mut self, plan: &FaultPlan) {
        if plan.is_empty() {
            return;
        }
        for (i, w) in self.workers.iter().enumerate() {
            w.send(WorkerMsg::Faults(plan.for_worker(i)));
        }
    }

    /// Override the reply deadline (`serve --worker-timeout SECONDS`);
    /// `None` returns to the cost-model EWMA deadline (ADR 008).
    pub fn set_worker_timeout(&mut self, seconds: Option<f64>) {
        self.health.set_timeout_override(seconds);
    }

    /// Declare a worker dead (ADR 008): flip liveness, then repair every
    /// structure that assumed it alive — reclaim its residency wholesale
    /// (no Evict messages; nobody is listening) and re-home experts it
    /// solely hosted onto survivors. Idempotent per worker; counts into
    /// the current stage's fault metrics and latches `degraded`.
    pub(crate) fn note_worker_death(&mut self, worker: usize, metrics: &mut StageMetrics) {
        if !self.health.mark_dead(worker) {
            return;
        }
        metrics.worker_deaths += 1;
        metrics.degraded = true;
        crate::util::logging::log(
            crate::util::logging::Level::Warn,
            "coordinator::server",
            format_args!(
                "worker {worker} declared dead (reply deadline exhausted); \
                 {} of {} workers remain",
                self.health.alive_count(),
                self.health.n_workers(),
            ),
        );
        self.residency.reclaim_worker(worker);
        self.placement.note_worker_death(worker);
    }

    /// Set (or clear) the per-worker byte cap for expert replica weights
    /// (`serve --memory-cap`, ADR 004). Serving under any cap is bitwise
    /// identical to unbounded serving — the cap trades refetch transfer
    /// for memory, never numerics.
    pub fn set_memory_cap(&mut self, cap_bytes: Option<u64>) {
        self.residency.set_cap(cap_bytes);
        // Plan-shrink diffing only runs while capped; re-seed its baseline
        // so a cap installed mid-run never diffs against stale placements.
        self.placement.reset_plan_baseline();
    }

    /// Read-only view of the residency LRU (replica sizing, counters,
    /// high-water mark); mutate only through coordinator serving methods.
    pub fn residency(&self) -> &ResidencyManager {
        &self.residency
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn seq_len(&self) -> usize {
        self.dims.seq_len
    }

    pub fn vocab(&self) -> usize {
        self.dims.vocab
    }

    /// Serve one round of requests; returns metrics and the final hidden
    /// states (per sequence, real tokens only).
    pub fn serve_round(&mut self, requests: &[Request]) -> Result<(RoundMetrics, Vec<HostTensor>)> {
        let round_start = Instant::now();
        self.round_tag += 1;
        let s_max = self.dims.seq_len;

        let mut metrics = RoundMetrics {
            n_seqs: requests.len(),
            worker_busy_s: vec![0.0; self.workers.len()],
            worker_slots: vec![0; self.workers.len()],
            ..Default::default()
        };

        // ---- 1. embed ---------------------------------------------------
        let t0 = Instant::now();
        let mut hidden: Vec<HostTensor> = Vec::with_capacity(requests.len());
        let mut n_real: Vec<usize> = Vec::with_capacity(requests.len());
        for req in requests {
            anyhow::ensure!(!req.tokens.is_empty(), "empty request {}", req.id);
            let n = req.tokens.len().min(s_max);
            let mut ids: Vec<i32> = req.tokens[..n].iter().map(|&t| t as i32).collect();
            ids.resize(s_max, 0);
            let ids = IntTensor::new(ids, vec![1, s_max]);
            let x0 = self
                .leader
                .call("embed", &[In::I(&ids), In::W("embed")])?
                .remove(0);
            hidden.push(x0);
            n_real.push(n);
            metrics.n_tokens += n;
        }
        metrics.embed_s = t0.elapsed().as_secs_f64();

        // ---- 2. predict + plan (shared stage) ---------------------------
        let plan_stage = self.build_plans(&hidden, &n_real, None)?;
        metrics.predictor_s = plan_stage.predictor_s;
        metrics.plan_s = plan_stage.plan_s;
        metrics.replicas_added = plan_stage.replicas_added;
        // Plan-shrink evictions happen at plan time, before the layer
        // loop's counter window opens (ADR 004).
        metrics.evictions += plan_stage.replicas_removed as u64;

        // ---- 3. unified per-layer pipeline ------------------------------
        let mut stage = StageMetrics::new(self.workers.len());
        // A window that *starts* short-handed is degraded even if no new
        // death lands inside it (ADR 008).
        stage.degraded |= self.health.alive_count() < self.workers.len();
        let mut mode = AttentionMode::Full {
            parallel: self.parallel_attention,
        };
        self.run_layers(
            &mut mode,
            &mut hidden,
            &n_real,
            &plan_stage.plans,
            plan_stage.predicted_experts.as_deref(),
            &mut stage,
        )?;
        stage.apply_to_round(&mut metrics);
        // Horizon forecasts parked at plan time mature inside the layer
        // loop's observes; score them into this round (ADR 006).
        let (forecast_l1, forecast_layers) = self.placement.drain_forecast_errors();
        metrics.forecast_l1 = forecast_l1;
        metrics.forecast_layers = forecast_layers;
        metrics.total_s = round_start.elapsed().as_secs_f64();

        // Trim outputs to real tokens.
        let outputs = hidden
            .iter()
            .zip(&n_real)
            .map(|(h, &n)| h.gather_rows(&(0..n).collect::<Vec<_>>()))
            .collect();
        Ok((metrics, outputs))
    }

    /// Serve many rounds and aggregate a report. With a controller
    /// installed (`serve --adaptive`), every round boundary is a replan
    /// (= layer-0) boundary where the strategy may be re-selected from
    /// the measured window (ADR 005) — never mid-forward, so the run is
    /// bitwise reproducible given the decision trace.
    pub fn serve(&mut self, rounds: Vec<Vec<Request>>) -> Result<ServeReport> {
        let mut report = ServeReport {
            strategy: self.strategy.name().to_string(),
            ..Default::default()
        };
        for (round_idx, round) in rounds.into_iter().enumerate() {
            if round_idx > 0 {
                self.consult_controller(round_idx);
            }
            let (metrics, _) = self.serve_round(&round)?;
            if let Some(ctrl) = self.controller.as_mut() {
                ctrl.observe_round(&metrics);
            }
            if metrics.worker_deaths > 0 {
                self.consult_on_worker_loss(round_idx);
            }
            report.rounds.push(metrics);
        }
        // Adaptive runs report the strategy they *ended* on; the decision
        // trace in `controller` replays how it got there.
        report.strategy = self.strategy.name().to_string();
        report.controller = self.controller.as_ref().map(|c| c.report(self.strategy));
        report.meta = self.report_meta("prefill");
        Ok(report)
    }

    /// The engine regime currently serving — what the controller prices
    /// its calibrated savings under (ADR 005).
    pub fn current_regime(&self) -> Regime {
        Regime {
            overlap: self.lookahead > 0,
            speculative: self.speculative,
            memory_cap_bytes: self.residency.cap_bytes().map(|b| b as f64),
            horizon: self.placement.horizon,
            // The sim's default drift stands in until the calibrator has a
            // measured realized forecast error to substitute (ADR 006).
            forecast_drift: None,
            microbatch: self.microbatch,
            // Copied-bytes pricing needs a measured report (`advise
            // --from-serve`, ADR 009 follow-up); live the sim default is 0.
            copied_bytes_per_token: None,
        }
    }

    /// Apply a controller decision. Only ever called at a layer-0
    /// boundary: numerics stay deterministic given the decision trace.
    pub fn apply_decision(&mut self, d: &Decision) {
        self.strategy = d.strategy;
        // Speculation rides TEP predictions + the lookahead pipeline.
        self.speculative = d.speculative && d.strategy == ServeStrategy::TokenToExpert;
        self.lookahead = d.lookahead;
        if self.speculative {
            self.lookahead = self.lookahead.max(1);
        }
        // Proactive horizon (0 = reactive). The controller lowers this to
        // 0 when realized forecast error breaches its threshold (ADR 006).
        self.placement.horizon = d.horizon;
        // Cached decode plans were built for the old regime; the next
        // step replans fresh.
        self.placement.reset_decode_plans();
    }

    fn consult_controller(&mut self, boundary: usize) {
        // Take the controller out so `decide` can borrow coordinator
        // state without aliasing it.
        let Some(mut ctrl) = self.controller.take() else {
            return;
        };
        let regime = self.current_regime();
        if let Some(d) =
            ctrl.decide(boundary, self.strategy, self.speculative, self.lookahead, regime)
        {
            self.apply_decision(&d);
        }
        self.controller = Some(ctrl);
    }

    fn report_meta(&self, phase: &str) -> ReportMeta {
        ReportMeta {
            phase: phase.into(),
            workers: self.workers.len(),
            lookahead: self.lookahead,
            speculative: self.speculative,
            memory_cap_bytes: self.residency.cap_bytes(),
            adaptive: self.controller.is_some(),
            horizon: self.placement.horizon,
            microbatch: self.microbatch,
            threads: crate::runtime::pool::threads(),
            pinned: crate::runtime::pool::pinning(),
            simd_tier: crate::runtime::simd::active_tier().name().into(),
        }
    }

    /// Serve requests with continuous batching: admit up to
    /// `opts.max_active` sequences, run prefill-then-decode per sequence,
    /// one token per active sequence per step, until every request's
    /// generation budget is spent (or `opts.max_steps` is hit).
    pub fn serve_decode(
        &mut self,
        requests: Vec<Request>,
        opts: &DecodeOptions,
    ) -> Result<DecodeReport> {
        // The AOT pipeline does not compile the decode artifacts yet, so
        // the decode pipeline needs the reference backend (which resolves
        // these ops lazily — load is a no-op there). Fail fast with
        // guidance instead of erroring mid-step under PJRT (DESIGN.md §6).
        for name in ["attention_prefill", "attention_step", "lm_head"] {
            self.leader.load(name).with_context(|| {
                format!(
                    "decode op `{name}` unavailable: AOT artifacts do not \
                     include decode ops yet, so `serve --phase decode` \
                     requires the reference backend (build without \
                     `--features pjrt`) — see DESIGN.md §6"
                )
            })?;
        }
        let mut report = DecodeReport {
            strategy: self.strategy.name().to_string(),
            ..Default::default()
        };
        let mut sched = Scheduler::new(opts.max_active);
        // Cap prompts at the compiled prefill bucket up front, so the
        // scheduler's bookkeeping (prompt_len, step_slot_bound) matches
        // exactly what the steps will route.
        let mut pending: VecDeque<Request> = requests
            .into_iter()
            .map(|mut r| {
                r.tokens.truncate(self.dims.seq_len.max(1));
                r
            })
            .collect();
        if opts.arrival_interval == 0 {
            while let Some(r) = pending.pop_front() {
                sched.push(r);
            }
        }
        let mut sessions: BTreeMap<u64, SeqSession> = BTreeMap::new();
        let mut rng = Rng::new(opts.seed ^ 0x00DE_C0DE);
        // Sequences evicted on an unrecoverable per-sequence fault: they
        // are neither finished nor requeued, but they were *explicitly*
        // handled, so end-of-run lost accounting excludes them (ADR 008).
        let mut faulted: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
        self.placement.reset_decode_plans();

        for step in 0..opts.max_steps {
            if self.health.alive_count() == 0 {
                break; // every worker dead: nothing can serve (ADR 008)
            }
            if opts.arrival_interval > 0 && step % opts.arrival_interval == 0 {
                if let Some(r) = pending.pop_front() {
                    sched.push(r);
                }
            }
            let admitted = sched.admit(step);
            if sched.active_len() == 0 {
                if pending.is_empty() {
                    break;
                }
                continue; // idle step waiting for the next arrival
            }
            // Controller consultation runs on the replan cadence
            // (`replan_interval` steps, the ADR-001 boundary) *uniformly
            // for every strategy*: gating on `replans_at` would consult
            // per step under TEP (which re-plans each step and never
            // fills the DOP plan cache), making hysteresis asymmetrically
            // twitchy and appending a DecisionRecord per step. Any step
            // start is a layer-0 boundary, so numerics stay deterministic
            // given the decision trace (ADR 005).
            let cadence = self.placement.replan_interval.max(1);
            if step > 0 && step % cadence == 0 {
                self.consult_controller(step);
            }
            let deaths_before = self.health.total_deaths;
            match self.decode_step(step, admitted, &mut sched, &mut sessions, opts, &mut rng) {
                Ok(metrics) => {
                    if let Some(ctrl) = self.controller.as_mut() {
                        ctrl.observe_step(&metrics);
                    }
                    // A worker died inside this step: give the controller
                    // an out-of-cadence boundary to shed optimism
                    // (speculation, deep lookahead) for the smaller
                    // cluster (ADR 008).
                    if metrics.worker_deaths > 0 {
                        self.consult_on_worker_loss(step);
                    }
                    report.steps.push(metrics);
                    for id in sched.evict_finished() {
                        sessions.remove(&id);
                    }
                }
                Err(err) if is_all_workers_dead(&err) => {
                    // No survivor can host any expert group: requeue every
                    // active sequence (full token history becomes the new
                    // prompt, remaining budget carries over) so nothing is
                    // lost, record the step as degraded, and stop serving.
                    let mut stub = DecodeStepMetrics {
                        step,
                        worker_deaths: self.health.total_deaths - deaths_before,
                        degraded: true,
                        worker_busy_s: vec![0.0; self.workers.len()],
                        worker_slots: vec![0; self.workers.len()],
                        ..Default::default()
                    };
                    let active: Vec<(u64, usize, usize)> = sched
                        .active()
                        .iter()
                        .map(|s| (s.id, s.max_new_tokens, s.generated))
                        .collect();
                    for (id, max_new, generated) in active {
                        let Some(sess) = sessions.remove(&id) else {
                            sched.drop_active(id);
                            faulted.insert(id);
                            continue;
                        };
                        let mut tokens = sess.tokens;
                        tokens.truncate(self.dims.seq_len.max(1));
                        sched.requeue(
                            Request::new(id, tokens)
                                .with_max_new_tokens(max_new.saturating_sub(generated).max(1)),
                        );
                        stub.requeued_seqs += 1;
                    }
                    report.steps.push(stub);
                    break;
                }
                Err(err) => match sequence_fault_id(&err) {
                    Some(id) => {
                        // Unrecoverable per-sequence state: evict the one
                        // sequence, keep serving the rest (ADR 008).
                        sessions.remove(&id);
                        sched.drop_active(id);
                        faulted.insert(id);
                        report.steps.push(DecodeStepMetrics {
                            step,
                            worker_deaths: self.health.total_deaths - deaths_before,
                            degraded: true,
                            worker_busy_s: vec![0.0; self.workers.len()],
                            worker_slots: vec![0; self.workers.len()],
                            ..Default::default()
                        });
                    }
                    None => return Err(err),
                },
            }
        }
        // Lost-sequence accounting over unique ids: everything admitted
        // must be finished, still waiting (requeued), still active (step
        // budget ran out), or explicitly evicted on a fault. Anything
        // else silently vanished — the invariant the chaos CI job pins
        // at zero (ADR 008).
        let mut outstanding: std::collections::BTreeSet<u64> =
            sched.admitted_order().iter().copied().collect();
        for id in sched.finished_order() {
            outstanding.remove(id);
        }
        for id in sched.waiting_ids() {
            outstanding.remove(&id);
        }
        for s in sched.active() {
            outstanding.remove(&s.id);
        }
        for id in &faulted {
            outstanding.remove(id);
        }
        report.lost_seqs = outstanding.len() as u64;
        report.strategy = self.strategy.name().to_string();
        report.controller = self.controller.as_ref().map(|c| c.report(self.strategy));
        report.meta = self.report_meta("decode");
        Ok(report)
    }

    /// Out-of-cadence controller consultation after a worker death: the
    /// step boundary is a legal layer-0 boundary, and the controller's
    /// `note_worker_lost` may shed speculation/lookahead for the smaller
    /// cluster (ADR 008).
    fn consult_on_worker_loss(&mut self, boundary: usize) {
        let Some(mut ctrl) = self.controller.take() else {
            return;
        };
        let regime = self.current_regime();
        if let Some(d) = ctrl.note_worker_lost(
            boundary,
            self.strategy,
            self.speculative,
            self.lookahead,
            regime,
        ) {
            self.apply_decision(&d);
        }
        self.controller = Some(ctrl);
    }

    /// One continuous-batching step (see module docs for the pipeline).
    fn decode_step(
        &mut self,
        step: usize,
        admitted: Vec<Request>,
        sched: &mut Scheduler,
        sessions: &mut BTreeMap<u64, SeqSession>,
        opts: &DecodeOptions,
        rng: &mut Rng,
    ) -> Result<DecodeStepMetrics> {
        let step_start = Instant::now();
        let n_layers = self.dims.n_layers;

        // Sessions for newly admitted requests (prompt capped at the
        // compiled prefill bucket).
        for req in &admitted {
            anyhow::ensure!(!req.tokens.is_empty(), "empty request {}", req.id);
            let mut tokens = req.tokens.clone();
            tokens.truncate(self.dims.seq_len);
            sessions.insert(
                req.id,
                SeqSession {
                    tokens,
                    kv: (0..n_layers).map(|_| None).collect(),
                },
            );
        }

        // Step workload in admission order: whole prompt for prefill
        // sequences, one row for decoding sequences. A missing session is
        // a per-sequence fault (evict it), not a panic (ADR 008).
        let mut workload: Vec<StepSeq> = Vec::with_capacity(sched.active().len());
        for s in sched.active() {
            let rows = match s.phase {
                SeqPhase::Prefill => {
                    let Some(sess) = sessions.get(&s.id) else {
                        return Err(sequence_fault_err(s.id, "session missing"));
                    };
                    sess.tokens.len()
                }
                _ => 1,
            };
            workload.push(StepSeq {
                id: s.id,
                rows,
                prefill: s.phase == SeqPhase::Prefill,
            });
        }

        let mut metrics = DecodeStepMetrics {
            step,
            n_seqs: workload.len(),
            worker_busy_s: vec![0.0; self.workers.len()],
            worker_slots: vec![0; self.workers.len()],
            ..Default::default()
        };

        // ---- 1. embed ---------------------------------------------------
        let t0 = Instant::now();
        let mut hidden: Vec<HostTensor> = Vec::with_capacity(workload.len());
        for ws in &workload {
            let Some(sess) = sessions.get(&ws.id) else {
                return Err(sequence_fault_err(ws.id, "session missing"));
            };
            let ids: Vec<i32> = if ws.prefill {
                sess.tokens.iter().map(|&t| t as i32).collect()
            } else {
                let Some(&last) = sess.tokens.last() else {
                    return Err(sequence_fault_err(ws.id, "empty session"));
                };
                vec![last as i32]
            };
            let n = ids.len();
            let ids = IntTensor::new(ids, vec![1, n]);
            let x0 = self
                .leader
                .call("embed", &[In::I(&ids), In::W("embed")])?
                .remove(0);
            hidden.push(x0);
            if ws.prefill {
                metrics.n_prefill_tokens += n;
            } else {
                metrics.n_decode_tokens += 1;
            }
        }
        metrics.embed_s = t0.elapsed().as_secs_f64();

        // ---- 2. predict + plan (shared stage) ---------------------------
        // DOP follows the replan cadence; TEP is re-priced every step
        // (its prediction covers exactly this step's new tokens — ADR 001).
        let n_real: Vec<usize> = workload.iter().map(|w| w.rows).collect();
        let plan_stage = self.build_plans(&hidden, &n_real, Some(step))?;
        // `DecodeStepMetrics` has no separate plan_s: planning folds into
        // predictor_s, matching the pre-refactor accounting.
        metrics.predictor_s = plan_stage.predictor_s + plan_stage.plan_s;
        metrics.replanned = plan_stage.replanned;
        metrics.replicas_added = plan_stage.replicas_added;
        metrics.evictions += plan_stage.replicas_removed as u64;

        // ---- 3. unified per-layer pipeline ------------------------------
        let mut stage = StageMetrics::new(self.workers.len());
        stage.degraded |= self.health.alive_count() < self.workers.len();
        {
            // Reborrow `sessions` so the lm-head stage below can use it
            // again after the pipeline releases the mode.
            let mut mode = AttentionMode::Cached {
                sessions: &mut *sessions,
                workload: &workload,
            };
            self.run_layers(
                &mut mode,
                &mut hidden,
                &n_real,
                &plan_stage.plans,
                plan_stage.predicted_experts.as_deref(),
                &mut stage,
            )?;
        }
        stage.apply_to_step(&mut metrics);
        // Score horizon forecasts that matured during this step's layer
        // observes (ADR 006).
        let (forecast_l1, forecast_layers) = self.placement.drain_forecast_errors();
        metrics.forecast_l1 = forecast_l1;
        metrics.forecast_layers = forecast_layers;

        // ---- 4. lm head + sampling --------------------------------------
        let t0 = Instant::now();
        for (i, ws) in workload.iter().enumerate() {
            let last = hidden[i].gather_rows(&[ws.rows - 1]);
            let logits = self
                .leader
                .call("lm_head", &[In::T(&last), In::W("final.ln"), In::W("embed")])?
                .remove(0);
            let token = sample_token(&logits.data, opts.temperature, rng);
            let Some(sess) = sessions.get_mut(&ws.id) else {
                return Err(sequence_fault_err(ws.id, "session missing"));
            };
            sess.tokens.push(token);
            sched.record_token(ws.id);
        }
        metrics.lm_head_s = t0.elapsed().as_secs_f64();

        metrics.total_s = step_start.elapsed().as_secs_f64();
        Ok(metrics)
    }
}

/// Sample the next token from lm-head logits: greedy when `temperature <=
/// 0`, else softmax sampling at the given temperature (deterministic given
/// the run's seeded RNG).
fn sample_token(logits: &[f32], temperature: f64, rng: &mut Rng) -> u32 {
    debug_assert!(!logits.is_empty());
    if temperature <= 0.0 {
        // Total order: a non-finite logit can never panic the serve path.
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0 as u32;
    }
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let probs: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - max) as f64) / temperature).exp())
        .collect();
    rng.categorical(&probs) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_token_greedy_is_argmax() {
        let mut rng = Rng::new(1);
        let logits = [0.1f32, 3.0, -2.0, 1.0];
        assert_eq!(sample_token(&logits, 0.0, &mut rng), 1);
    }

    #[test]
    fn sample_token_tracks_distribution() {
        let mut rng = Rng::new(2);
        // One dominant logit: sampling should pick it most of the time.
        let logits = [0.0f32, 5.0, 0.0, 0.0];
        let hits = (0..200)
            .filter(|_| sample_token(&logits, 1.0, &mut rng) == 1)
            .count();
        assert!(hits > 150, "hits={hits}");
    }

    #[test]
    fn decode_options_defaults_sane() {
        let opts = DecodeOptions::default();
        assert!(opts.max_active >= 1);
        assert!(opts.max_steps > 0);
        assert_eq!(opts.arrival_interval, 0);
    }
}
