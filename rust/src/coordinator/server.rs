//! The serving leader: drives a full prefill round through the AOT model
//! under Expert Parallelism with predictor-driven dynamic duplication.
//!
//! Round pipeline (per paper Figure 3):
//!
//! 1. embed every sequence (leader engine);
//! 2. *Token-to-Expert*: run the AOT predictor on the embeddings — before
//!    attention, §3.1 — and build per-layer duplication plans;
//!    *Distribution-Only*: build plans from the online MLE estimators;
//! 3. per layer: attention (leader), fused router kernel, rust top-k;
//! 4. dispatch routed token-slots to virtual-GPU workers per the plan
//!    (quota dispatch for TEP, least-loaded over replicas for DOP, home
//!    GPU for the baseline);
//! 5. workers execute the Pallas expert-FFN artifact; leader gates and
//!    combines outputs into the residual stream;
//! 6. estimators observe the actual routing (the §3.2.1 moving average).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::metrics::{RoundMetrics, ServeReport};
use super::placement_mgr::{LayerPlan, PlacementManager};
use super::request::Request;
use super::router::{expert_counts, route_sequence, Slot};
use super::worker::{pad_to_bucket, WorkerHandle, WorkerMsg, WorkerResult};
use crate::duplication::dispatch::{dispatch_tokens, dispatch_with_quota};
use crate::runtime::{Engine, HostTensor, In};
use crate::runtime::tensor::IntTensor;
use crate::util::stats;

/// Which prediction strategy drives placement (paper §3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeStrategy {
    NoPrediction,
    DistributionOnly,
    TokenToExpert,
}

impl ServeStrategy {
    pub fn name(self) -> &'static str {
        match self {
            ServeStrategy::NoPrediction => "none",
            ServeStrategy::DistributionOnly => "distribution-only",
            ServeStrategy::TokenToExpert => "token-to-expert",
        }
    }

    pub fn by_name(s: &str) -> Result<ServeStrategy> {
        match s {
            "none" | "baseline" => Ok(ServeStrategy::NoPrediction),
            "distribution-only" | "dop" => Ok(ServeStrategy::DistributionOnly),
            "token-to-expert" | "tep" => Ok(ServeStrategy::TokenToExpert),
            other => anyhow::bail!("unknown strategy `{other}`"),
        }
    }
}

/// Model dims read from the artifact manifest.
#[derive(Clone, Debug)]
struct Dims {
    d_model: usize,
    n_experts: usize,
    n_layers: usize,
    top_k: usize,
    seq_len: usize,
    vocab: usize,
}

pub struct Coordinator {
    leader: Engine,
    workers: Vec<WorkerHandle>,
    pub placement: PlacementManager,
    pub strategy: ServeStrategy,
    dims: Dims,
    buckets: Vec<usize>,
    round_tag: u64,
    /// §Perf iteration 2: fan per-sequence attention out to the workers
    /// (the TP analogue). Measured neutral on this substrate — the PJRT
    /// CPU client already saturates all cores per execution, so parallel
    /// clients contend; on real multi-device hardware this is the right
    /// topology. Default off (leader attention); kept selectable + tested.
    pub parallel_attention: bool,
}

impl Coordinator {
    /// Build a coordinator with `n_workers` virtual GPUs over the
    /// artifacts directory.
    pub fn new(
        artifacts_dir: &Path,
        n_workers: usize,
        strategy: ServeStrategy,
    ) -> Result<Coordinator> {
        let mut leader = Engine::new(artifacts_dir).context("leader engine")?;
        let cfg = leader.manifest().config.clone();
        let dims = Dims {
            d_model: cfg.req_usize("d_model")?,
            n_experts: cfg.req_usize("n_experts")?,
            n_layers: cfg.req_usize("n_layers")?,
            top_k: cfg.req_usize("top_k")?,
            seq_len: cfg.req_usize("seq_len")?,
            vocab: cfg.req_usize("vocab_size")?,
        };
        let buckets = leader.manifest().ffn_buckets();
        anyhow::ensure!(!buckets.is_empty(), "no expert_ffn buckets in manifest");

        // Pre-compile the leader path.
        for name in ["embed", "attention", "router", "predictor"] {
            leader.load(name)?;
        }

        let workers: Vec<WorkerHandle> = (0..n_workers)
            .map(|i| WorkerHandle::spawn(i, PathBuf::from(artifacts_dir)))
            .collect::<Result<_>>()?;

        // Capacity: up to all experts can fit (CPU memory is not the
        // constraint here); C_max = n_workers mirrors "replicate at most
        // once per GPU".
        let placement = PlacementManager::new(
            dims.n_experts,
            n_workers,
            dims.n_layers,
            dims.n_experts,
            n_workers,
        );

        Ok(Coordinator {
            leader,
            workers,
            placement,
            strategy,
            dims,
            buckets,
            round_tag: 0,
            parallel_attention: false,
        })
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn seq_len(&self) -> usize {
        self.dims.seq_len
    }

    pub fn vocab(&self) -> usize {
        self.dims.vocab
    }

    /// Serve one round of requests; returns metrics and the final hidden
    /// states (per sequence, real tokens only).
    pub fn serve_round(&mut self, requests: &[Request]) -> Result<(RoundMetrics, Vec<HostTensor>)> {
        let round_start = Instant::now();
        self.round_tag += 1;
        let s_max = self.dims.seq_len;
        let d = self.dims.d_model;
        let e = self.dims.n_experts;

        let mut metrics = RoundMetrics {
            n_seqs: requests.len(),
            worker_busy_s: vec![0.0; self.workers.len()],
            worker_slots: vec![0; self.workers.len()],
            ..Default::default()
        };

        // ---- 1. embed ---------------------------------------------------
        let t0 = Instant::now();
        let mut hidden: Vec<HostTensor> = Vec::with_capacity(requests.len());
        let mut n_real: Vec<usize> = Vec::with_capacity(requests.len());
        for req in requests {
            anyhow::ensure!(!req.tokens.is_empty(), "empty request {}", req.id);
            let n = req.tokens.len().min(s_max);
            let mut ids: Vec<i32> = req.tokens[..n].iter().map(|&t| t as i32).collect();
            ids.resize(s_max, 0);
            let ids = IntTensor::new(ids, vec![1, s_max]);
            let x0 = self
                .leader
                .call("embed", &[In::I(&ids), In::W("embed")])?
                .remove(0);
            hidden.push(x0);
            n_real.push(n);
            metrics.n_tokens += n;
        }
        metrics.embed_s = t0.elapsed().as_secs_f64();

        // ---- 2. predict + plan ------------------------------------------
        let t0 = Instant::now();
        let plans: Vec<LayerPlan> = match self.strategy {
            ServeStrategy::NoPrediction => {
                (0..self.dims.n_layers).map(|_| self.placement.static_plan()).collect()
            }
            ServeStrategy::DistributionOnly => {
                let total_slots: usize =
                    n_real.iter().map(|&n| n * self.dims.top_k).sum();
                (0..self.dims.n_layers)
                    .map(|l| self.placement.plan_distribution_only(l, total_slots))
                    .collect()
            }
            ServeStrategy::TokenToExpert => {
                // AOT predictor on every sequence's embeddings (§3.1:
                // before attention).
                let mut counts = vec![vec![0usize; e]; self.dims.n_layers];
                let head_names: Vec<String> = (0..self.dims.n_layers)
                    .map(|l| format!("predictor.head.{l}"))
                    .collect();
                for (seq, &n) in hidden.iter().zip(&n_real) {
                    let mut ins: Vec<In<'_>> = vec![
                        In::T(seq),
                        In::W("predictor.w1"),
                        In::W("predictor.b1"),
                    ];
                    for name in &head_names {
                        ins.push(In::W(name));
                    }
                    let logits = self.leader.call("predictor", &ins)?.remove(0);
                    // logits [L, S, E]: argmax per (layer, real token).
                    for l in 0..self.dims.n_layers {
                        for t in 0..n {
                            let base = (l * s_max + t) * e;
                            let row = &logits.data[base..base + e];
                            let arg = row
                                .iter()
                                .enumerate()
                                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                                .unwrap()
                                .0;
                            // Each token occupies top_k slots; scale the
                            // predicted count accordingly.
                            counts[l][arg] += self.dims.top_k;
                        }
                    }
                }
                counts
                    .iter()
                    .map(|c| self.placement.plan_from_counts(c))
                    .collect()
            }
        };
        metrics.predictor_s = t0.elapsed().as_secs_f64();
        metrics.replicas_added = plans.iter().map(|p| p.added.len()).sum();
        metrics.plan_s = 0.0; // planning time folded into predictor_s

        // ---- 3..5 per-layer pipeline ------------------------------------
        let mut skews: Vec<f64> = Vec::new();
        for layer in 0..self.dims.n_layers {
            // Attention: sequences of the round spread across the virtual
            // GPUs and run in parallel (the serving analogue of the paper's
            // TP attention — §Perf iteration 2; single-sequence rounds fall
            // back to the leader to avoid a round-trip).
            let t0 = Instant::now();
            if !self.parallel_attention || hidden.len() == 1 {
                let attn_names = [
                    format!("layers.{layer}.attn.ln"),
                    format!("layers.{layer}.attn.wq"),
                    format!("layers.{layer}.attn.wk"),
                    format!("layers.{layer}.attn.wv"),
                    format!("layers.{layer}.attn.wo"),
                ];
                for h in hidden.iter_mut() {
                    let out = self
                        .leader
                        .call(
                            "attention",
                            &[
                                In::T(h),
                                In::W(&attn_names[0]),
                                In::W(&attn_names[1]),
                                In::W(&attn_names[2]),
                                In::W(&attn_names[3]),
                                In::W(&attn_names[4]),
                            ],
                        )?
                        .remove(0);
                    *h = out;
                }
            } else {
                let (attn_tx, attn_rx) = mpsc::channel::<WorkerResult>();
                for (seq_idx, h) in hidden.iter().enumerate() {
                    let worker = seq_idx % self.workers.len();
                    self.workers[worker].send(WorkerMsg::Attention {
                        tag: seq_idx as u64,
                        layer,
                        x: h.clone(),
                        reply: attn_tx.clone(),
                    });
                }
                drop(attn_tx);
                for _ in 0..hidden.len() {
                    let r = attn_rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("attention worker channel closed"))?;
                    if let Some(err) = &r.error {
                        anyhow::bail!("attention on worker {} failed: {err}", r.worker);
                    }
                    let shape = hidden[r.tag as usize].shape.clone();
                    hidden[r.tag as usize] = HostTensor::new(r.out, shape);
                }
            }
            metrics.attention_s += t0.elapsed().as_secs_f64();

            // Router (fused Pallas RMSNorm + logits) + rust top-k.
            let t0 = Instant::now();
            let ln = format!("layers.{layer}.moe.ln");
            let wr = format!("layers.{layer}.moe.router");
            let mut normed: Vec<HostTensor> = Vec::with_capacity(hidden.len());
            let mut slots: Vec<Slot> = Vec::new();
            for (seq_idx, h) in hidden.iter().enumerate() {
                let mut out = self
                    .leader
                    .call("router", &[In::T(h), In::W(&ln), In::W(&wr)])?;
                let logits = out.remove(1);
                let xn = out.remove(0);
                slots.extend(route_sequence(
                    seq_idx,
                    &logits.data,
                    e,
                    n_real[seq_idx],
                    self.dims.top_k,
                ));
                normed.push(xn);
            }
            let actual_counts = expert_counts(&slots, e);
            skews.push(stats::skewness_of_counts(&actual_counts));
            metrics.n_slots += slots.len();
            metrics.router_s += t0.elapsed().as_secs_f64();

            // Dispatch: assign every slot a worker under the plan.
            let plan = &plans[layer];
            let experts: Vec<u8> = slots.iter().map(|s| s.expert).collect();
            let (assignment, _loads) = if plan.share.is_empty() {
                dispatch_tokens(&experts, &plan.placement)
            } else {
                dispatch_with_quota(&experts, &plan.placement, &plan.share)
            };

            // Group slots per (worker, expert), gather activations, run.
            let t0 = Instant::now();
            let mut groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
            for (slot_idx, (&slot_worker, slot)) in
                assignment.iter().zip(&slots).enumerate()
            {
                groups
                    .entry((slot_worker as usize, slot.expert as usize))
                    .or_default()
                    .push(slot_idx);
            }
            // §Perf: merge runt groups. Splitting an expert across workers
            // for a handful of slots costs a whole padded-bucket FFN call
            // (and possibly a weight transfer) for negligible balance gain;
            // fold any group smaller than MIN_GROUP into the largest group
            // of the same expert.
            const MIN_GROUP: usize = 16;
            let expert_ids: Vec<usize> =
                groups.keys().map(|&(_, e)| e).collect::<std::collections::BTreeSet<_>>().into_iter().collect();
            for expert in expert_ids {
                let mut keys: Vec<(usize, usize)> = groups
                    .keys()
                    .filter(|&&(_, ge)| ge == expert)
                    .cloned()
                    .collect();
                if keys.len() < 2 {
                    continue;
                }
                keys.sort_by_key(|k| groups[k].len());
                let biggest = *keys.last().unwrap();
                for key in &keys[..keys.len() - 1] {
                    if groups[key].len() < MIN_GROUP {
                        let moved = groups.remove(key).unwrap();
                        groups.get_mut(&biggest).unwrap().extend(moved);
                    }
                }
            }
            let (reply_tx, reply_rx) = mpsc::channel::<WorkerResult>();
            let mut outstanding = 0usize;
            // slot order metadata for combining.
            let mut group_slots: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
            let mut msg_tag = 0u64;
            for ((worker, expert), slot_indices) in &groups {
                // Gather the normed activations for these slots.
                let mut data = Vec::with_capacity(slot_indices.len() * d);
                for &si in slot_indices {
                    let slot = &slots[si];
                    data.extend_from_slice(
                        &normed[slot.seq_idx].row(slot.token_idx),
                    );
                }
                let xn = HostTensor::new(data, vec![slot_indices.len(), d]);
                // Oversized groups split across bucket-sized chunks.
                let mut offset = 0usize;
                for (chunk, _bucket) in
                    crate::runtime::bucket::split_into_buckets(&self.buckets, xn.rows())
                {
                    let rows: Vec<usize> = (offset..offset + chunk).collect();
                    let tile = pad_to_bucket(xn.gather_rows(&rows), &self.buckets);
                    msg_tag += 1;
                    group_slots.insert(msg_tag, slot_indices[offset..offset + chunk].to_vec());
                    self.workers[*worker].send(WorkerMsg::Run {
                        tag: msg_tag,
                        layer,
                        expert: *expert,
                        xn: tile,
                        n_real: chunk,
                        reply: reply_tx.clone(),
                    });
                    outstanding += 1;
                    metrics.worker_slots[*worker] += chunk;
                    offset += chunk;
                }
            }
            drop(reply_tx);

            // Combine: h += gate * expert_out at each slot.
            let mut received = 0usize;
            while received < outstanding {
                let result = reply_rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("worker channel closed"))?;
                received += 1;
                if let Some(err) = &result.error {
                    anyhow::bail!("worker {} failed: {err}", result.worker);
                }
                metrics.worker_busy_s[result.worker] += result.exec_s;
                metrics.upload_bytes += result.upload_bytes;
                let slot_indices = &group_slots[&result.tag];
                debug_assert_eq!(result.n_real, slot_indices.len());
                for (row, &si) in slot_indices.iter().enumerate() {
                    let slot = &slots[si];
                    let out_row = &result.out[row * d..(row + 1) * d];
                    let h = &mut hidden[slot.seq_idx];
                    let dst = &mut h.data[slot.token_idx * d..(slot.token_idx + 1) * d];
                    for (a, &b) in dst.iter_mut().zip(out_row) {
                        *a += slot.gate * b;
                    }
                }
            }
            metrics.ffn_wall_s += t0.elapsed().as_secs_f64();

            // Online learning for the DOP estimators.
            self.placement.observe(layer, &actual_counts);
        }

        metrics.routing_skew = stats::mean(&skews);
        metrics.total_s = round_start.elapsed().as_secs_f64();

        // Trim outputs to real tokens.
        let outputs = hidden
            .iter()
            .zip(&n_real)
            .map(|(h, &n)| h.gather_rows(&(0..n).collect::<Vec<_>>()))
            .collect();
        Ok((metrics, outputs))
    }

    /// Serve many rounds and aggregate a report.
    pub fn serve(&mut self, rounds: Vec<Vec<Request>>) -> Result<ServeReport> {
        let mut report = ServeReport {
            strategy: self.strategy.name().to_string(),
            rounds: Vec::new(),
        };
        for round in rounds {
            let (metrics, _) = self.serve_round(&round)?;
            report.rounds.push(metrics);
        }
        Ok(report)
    }
}
