//! The serving leader: drives prefill rounds and continuous-batching
//! decode steps through the AOT model under Expert Parallelism with
//! predictor-driven dynamic duplication.
//!
//! Prefill round pipeline (per paper Figure 3):
//!
//! 1. embed every sequence (leader engine);
//! 2. *Token-to-Expert*: run the AOT predictor on the embeddings — before
//!    attention, §3.1 — and build per-layer duplication plans;
//!    *Distribution-Only*: build plans from the online MLE estimators;
//! 3. per layer: attention (leader), fused router kernel, rust top-k;
//! 4. dispatch routed token-slots to virtual-GPU workers per the plan
//!    (quota dispatch for TEP, least-loaded over replicas for DOP, home
//!    GPU for the baseline);
//! 5. workers execute the expert-FFN artifact; leader gates and combines
//!    outputs into the residual stream;
//! 6. estimators observe the actual routing (the §3.2.1 moving average).
//!
//! Decode step pipeline ([`Coordinator::serve_decode`], DESIGN.md §4):
//! every step carries one token per decoding sequence plus the full prompt
//! of each newly admitted sequence (continuous batching — admission and
//! eviction are iteration-level, per [`super::scheduler`]). Attention runs
//! incrementally over per-sequence KV caches; routing, dispatch and expert
//! FFN reuse the same machinery as prefill; the DOP estimators update
//! every step while Algorithm-1 replanning follows the
//! `PlacementManager::replan_interval` cadence (ADR 001).

use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::metrics::{DecodeReport, DecodeStepMetrics, RoundMetrics, ServeReport};
use super::placement_mgr::{LayerPlan, PlacementManager};
use super::request::Request;
use super::router::{expert_counts, route_sequence, Slot};
use super::scheduler::{Scheduler, SeqPhase};
use super::worker::{pad_to_bucket, WorkerHandle, WorkerMsg, WorkerResult};
use crate::duplication::dispatch::{dispatch_tokens, dispatch_with_quota};
use crate::runtime::tensor::IntTensor;
use crate::runtime::{Engine, EngineSource, HostTensor, In};
use crate::util::rng::Rng;
use crate::util::stats;

/// Which prediction strategy drives placement (paper §3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeStrategy {
    NoPrediction,
    DistributionOnly,
    TokenToExpert,
}

impl ServeStrategy {
    pub fn name(self) -> &'static str {
        match self {
            ServeStrategy::NoPrediction => "none",
            ServeStrategy::DistributionOnly => "distribution-only",
            ServeStrategy::TokenToExpert => "token-to-expert",
        }
    }

    pub fn by_name(s: &str) -> Result<ServeStrategy> {
        match s {
            "none" | "baseline" => Ok(ServeStrategy::NoPrediction),
            "distribution-only" | "dop" => Ok(ServeStrategy::DistributionOnly),
            "token-to-expert" | "tep" => Ok(ServeStrategy::TokenToExpert),
            other => anyhow::bail!("unknown strategy `{other}`"),
        }
    }
}

/// Model dims read from the artifact manifest.
#[derive(Clone, Debug)]
struct Dims {
    d_model: usize,
    n_experts: usize,
    n_layers: usize,
    top_k: usize,
    seq_len: usize,
    vocab: usize,
}

/// Knobs for a continuous-batching decode run.
#[derive(Clone, Debug)]
pub struct DecodeOptions {
    /// Maximum concurrently active sequences (the continuous batch size).
    pub max_active: usize,
    /// Hard step budget for the run.
    pub max_steps: usize,
    /// Sampling temperature; `<= 0` = greedy argmax.
    pub temperature: f64,
    /// Sampling seed (the run is deterministic given it).
    pub seed: u64,
    /// 0 = all requests arrive up front (pure decode after warmup);
    /// N > 0 = one queued request arrives every N steps (`--phase mixed`).
    pub arrival_interval: usize,
}

impl Default for DecodeOptions {
    fn default() -> Self {
        DecodeOptions {
            max_active: 8,
            max_steps: 512,
            temperature: 1.0,
            seed: 17,
            arrival_interval: 0,
        }
    }
}

/// Per-sequence tensors the decode path keeps across steps.
struct SeqSession {
    /// Prompt plus generated tokens.
    tokens: Vec<u32>,
    /// Per-layer (K, V) caches, `[t, n_kv_heads * head_dim]`.
    kv: Vec<Option<(HostTensor, HostTensor)>>,
}

/// One sequence's share of a decode step.
struct StepSeq {
    id: u64,
    rows: usize,
    prefill: bool,
}

/// What one FFN dispatch phase produced (shared by prefill rounds and
/// decode steps).
struct FfnPhaseOutcome {
    wall_s: f64,
    worker_busy_s: Vec<f64>,
    worker_slots: Vec<usize>,
    upload_bytes: u64,
}

pub struct Coordinator {
    leader: Engine,
    workers: Vec<WorkerHandle>,
    pub placement: PlacementManager,
    pub strategy: ServeStrategy,
    dims: Dims,
    buckets: Vec<usize>,
    round_tag: u64,
    /// §Perf iteration 2: fan per-sequence attention out to the workers
    /// (the TP analogue). Measured neutral on this substrate — the PJRT
    /// CPU client already saturates all cores per execution, so parallel
    /// clients contend; on real multi-device hardware this is the right
    /// topology. Default off (leader attention); kept selectable + tested.
    /// Applies to prefill rounds; decode attention always runs on the
    /// leader (single-row matvecs — a worker round-trip costs more than
    /// the op).
    pub parallel_attention: bool,
}

impl Coordinator {
    /// Build a coordinator with `n_workers` virtual GPUs over the
    /// artifacts directory, falling back to the synthetic tiny model when
    /// no artifacts exist (so serving works in every build environment).
    pub fn new(
        artifacts_dir: &Path,
        n_workers: usize,
        strategy: ServeStrategy,
    ) -> Result<Coordinator> {
        let source = EngineSource::detect(artifacts_dir);
        if source.is_synthetic() {
            crate::util::logging::log(
                crate::util::logging::Level::Info,
                "coordinator::server",
                format_args!(
                    "no artifacts at {}; serving the synthetic tiny model \
                     (reference backend)",
                    artifacts_dir.display()
                ),
            );
        }
        Coordinator::with_source(&source, n_workers, strategy)
    }

    /// Build a coordinator over an explicit engine source.
    pub fn with_source(
        source: &EngineSource,
        n_workers: usize,
        strategy: ServeStrategy,
    ) -> Result<Coordinator> {
        let mut leader = Engine::from_source(source).context("leader engine")?;
        let cfg = leader.manifest().config.clone();
        let dims = Dims {
            d_model: cfg.req_usize("d_model")?,
            n_experts: cfg.req_usize("n_experts")?,
            n_layers: cfg.req_usize("n_layers")?,
            top_k: cfg.req_usize("top_k")?,
            seq_len: cfg.req_usize("seq_len")?,
            vocab: cfg.req_usize("vocab_size")?,
        };
        let buckets = leader.manifest().ffn_buckets();
        anyhow::ensure!(!buckets.is_empty(), "no expert_ffn buckets in manifest");

        // Pre-compile the leader path.
        for name in ["embed", "attention", "router", "predictor"] {
            leader.load(name)?;
        }

        let workers: Vec<WorkerHandle> = (0..n_workers)
            .map(|i| WorkerHandle::spawn(i, source.clone()))
            .collect::<Result<_>>()?;

        // Capacity: up to all experts can fit (CPU memory is not the
        // constraint here); C_max = n_workers mirrors "replicate at most
        // once per GPU".
        let placement = PlacementManager::new(
            dims.n_experts,
            n_workers,
            dims.n_layers,
            dims.n_experts,
            n_workers,
        );

        Ok(Coordinator {
            leader,
            workers,
            placement,
            strategy,
            dims,
            buckets,
            round_tag: 0,
            parallel_attention: false,
        })
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn seq_len(&self) -> usize {
        self.dims.seq_len
    }

    pub fn vocab(&self) -> usize {
        self.dims.vocab
    }

    /// Serve one round of requests; returns metrics and the final hidden
    /// states (per sequence, real tokens only).
    pub fn serve_round(&mut self, requests: &[Request]) -> Result<(RoundMetrics, Vec<HostTensor>)> {
        let round_start = Instant::now();
        self.round_tag += 1;
        let s_max = self.dims.seq_len;
        let e = self.dims.n_experts;

        let mut metrics = RoundMetrics {
            n_seqs: requests.len(),
            worker_busy_s: vec![0.0; self.workers.len()],
            worker_slots: vec![0; self.workers.len()],
            ..Default::default()
        };

        // ---- 1. embed ---------------------------------------------------
        let t0 = Instant::now();
        let mut hidden: Vec<HostTensor> = Vec::with_capacity(requests.len());
        let mut n_real: Vec<usize> = Vec::with_capacity(requests.len());
        for req in requests {
            anyhow::ensure!(!req.tokens.is_empty(), "empty request {}", req.id);
            let n = req.tokens.len().min(s_max);
            let mut ids: Vec<i32> = req.tokens[..n].iter().map(|&t| t as i32).collect();
            ids.resize(s_max, 0);
            let ids = IntTensor::new(ids, vec![1, s_max]);
            let x0 = self
                .leader
                .call("embed", &[In::I(&ids), In::W("embed")])?
                .remove(0);
            hidden.push(x0);
            n_real.push(n);
            metrics.n_tokens += n;
        }
        metrics.embed_s = t0.elapsed().as_secs_f64();

        // ---- 2. predict + plan ------------------------------------------
        let t0 = Instant::now();
        let plans: Vec<LayerPlan> = match self.strategy {
            ServeStrategy::NoPrediction => {
                (0..self.dims.n_layers).map(|_| self.placement.static_plan()).collect()
            }
            ServeStrategy::DistributionOnly => {
                let total_slots: usize =
                    n_real.iter().map(|&n| n * self.dims.top_k).sum();
                (0..self.dims.n_layers)
                    .map(|l| self.placement.plan_distribution_only(l, total_slots))
                    .collect()
            }
            ServeStrategy::TokenToExpert => {
                let counts = self.predict_counts(&hidden, &n_real)?;
                counts
                    .iter()
                    .map(|c| self.placement.plan_from_counts(c))
                    .collect()
            }
        };
        metrics.predictor_s = t0.elapsed().as_secs_f64();
        metrics.replicas_added = plans.iter().map(|p| p.added.len()).sum();
        metrics.plan_s = 0.0; // planning time folded into predictor_s

        // ---- 3..5 per-layer pipeline ------------------------------------
        let mut skews: Vec<f64> = Vec::new();
        for layer in 0..self.dims.n_layers {
            // Attention: sequences of the round spread across the virtual
            // GPUs and run in parallel (the serving analogue of the paper's
            // TP attention — §Perf iteration 2; single-sequence rounds fall
            // back to the leader to avoid a round-trip).
            let t0 = Instant::now();
            if !self.parallel_attention || hidden.len() == 1 {
                let attn_names = attn_weight_names(layer);
                for h in hidden.iter_mut() {
                    let out = self
                        .leader
                        .call(
                            "attention",
                            &[
                                In::T(h),
                                In::W(&attn_names[0]),
                                In::W(&attn_names[1]),
                                In::W(&attn_names[2]),
                                In::W(&attn_names[3]),
                                In::W(&attn_names[4]),
                            ],
                        )?
                        .remove(0);
                    *h = out;
                }
            } else {
                let (attn_tx, attn_rx) = mpsc::channel::<WorkerResult>();
                for (seq_idx, h) in hidden.iter().enumerate() {
                    let worker = seq_idx % self.workers.len();
                    self.workers[worker].send(WorkerMsg::Attention {
                        tag: seq_idx as u64,
                        layer,
                        x: h.clone(),
                        reply: attn_tx.clone(),
                    });
                }
                drop(attn_tx);
                for _ in 0..hidden.len() {
                    let r = attn_rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("attention worker channel closed"))?;
                    if let Some(err) = &r.error {
                        anyhow::bail!("attention on worker {} failed: {err}", r.worker);
                    }
                    let shape = hidden[r.tag as usize].shape.clone();
                    hidden[r.tag as usize] = HostTensor::new(r.out, shape);
                }
            }
            metrics.attention_s += t0.elapsed().as_secs_f64();

            // Router (fused RMSNorm + logits) + rust top-k.
            let t0 = Instant::now();
            let ln = format!("layers.{layer}.moe.ln");
            let wr = format!("layers.{layer}.moe.router");
            let mut normed: Vec<HostTensor> = Vec::with_capacity(hidden.len());
            let mut slots: Vec<Slot> = Vec::new();
            for (seq_idx, h) in hidden.iter().enumerate() {
                let mut out = self
                    .leader
                    .call("router", &[In::T(h), In::W(&ln), In::W(&wr)])?;
                let logits = out.remove(1);
                let xn = out.remove(0);
                slots.extend(route_sequence(
                    seq_idx,
                    &logits.data,
                    e,
                    n_real[seq_idx],
                    self.dims.top_k,
                ));
                normed.push(xn);
            }
            let actual_counts = expert_counts(&slots, e);
            skews.push(stats::skewness_of_counts(&actual_counts));
            metrics.n_slots += slots.len();
            metrics.router_s += t0.elapsed().as_secs_f64();

            // Dispatch + expert FFN + combine (shared with decode).
            let outcome = self.ffn_phase(layer, &plans[layer], &slots, &normed, &mut hidden)?;
            for (w, &b) in outcome.worker_busy_s.iter().enumerate() {
                metrics.worker_busy_s[w] += b;
            }
            for (w, &s) in outcome.worker_slots.iter().enumerate() {
                metrics.worker_slots[w] += s;
            }
            metrics.upload_bytes += outcome.upload_bytes;
            metrics.ffn_wall_s += outcome.wall_s;

            // Online learning for the DOP estimators.
            self.placement.observe(layer, &actual_counts);
        }

        metrics.routing_skew = stats::mean(&skews);
        metrics.total_s = round_start.elapsed().as_secs_f64();

        // Trim outputs to real tokens.
        let outputs = hidden
            .iter()
            .zip(&n_real)
            .map(|(h, &n)| h.gather_rows(&(0..n).collect::<Vec<_>>()))
            .collect();
        Ok((metrics, outputs))
    }

    /// Serve many rounds and aggregate a report.
    pub fn serve(&mut self, rounds: Vec<Vec<Request>>) -> Result<ServeReport> {
        let mut report = ServeReport {
            strategy: self.strategy.name().to_string(),
            rounds: Vec::new(),
        };
        for round in rounds {
            let (metrics, _) = self.serve_round(&round)?;
            report.rounds.push(metrics);
        }
        Ok(report)
    }

    /// Serve requests with continuous batching: admit up to
    /// `opts.max_active` sequences, run prefill-then-decode per sequence,
    /// one token per active sequence per step, until every request's
    /// generation budget is spent (or `opts.max_steps` is hit).
    pub fn serve_decode(
        &mut self,
        requests: Vec<Request>,
        opts: &DecodeOptions,
    ) -> Result<DecodeReport> {
        // The AOT pipeline does not compile the decode artifacts yet, so
        // the decode pipeline needs the reference backend (which resolves
        // these ops lazily — load is a no-op there). Fail fast with
        // guidance instead of erroring mid-step under PJRT (DESIGN.md §6).
        for name in ["attention_prefill", "attention_step", "lm_head"] {
            self.leader.load(name).with_context(|| {
                format!(
                    "decode op `{name}` unavailable: AOT artifacts do not \
                     include decode ops yet, so `serve --phase decode` \
                     requires the reference backend (build without \
                     `--features pjrt`) — see DESIGN.md §6"
                )
            })?;
        }
        let mut report = DecodeReport {
            strategy: self.strategy.name().to_string(),
            steps: Vec::new(),
        };
        let mut sched = Scheduler::new(opts.max_active);
        // Cap prompts at the compiled prefill bucket up front, so the
        // scheduler's bookkeeping (prompt_len, step_slot_bound) matches
        // exactly what the steps will route.
        let mut pending: VecDeque<Request> = requests
            .into_iter()
            .map(|mut r| {
                r.tokens.truncate(self.dims.seq_len.max(1));
                r
            })
            .collect();
        if opts.arrival_interval == 0 {
            while let Some(r) = pending.pop_front() {
                sched.push(r);
            }
        }
        let mut sessions: BTreeMap<u64, SeqSession> = BTreeMap::new();
        let mut rng = Rng::new(opts.seed ^ 0x00DE_C0DE);
        self.placement.reset_decode_plans();

        for step in 0..opts.max_steps {
            if opts.arrival_interval > 0 && step % opts.arrival_interval == 0 {
                if let Some(r) = pending.pop_front() {
                    sched.push(r);
                }
            }
            let admitted = sched.admit(step);
            if sched.active_len() == 0 {
                if pending.is_empty() {
                    break;
                }
                continue; // idle step waiting for the next arrival
            }
            let metrics =
                self.decode_step(step, admitted, &mut sched, &mut sessions, opts, &mut rng)?;
            report.steps.push(metrics);
            for id in sched.evict_finished() {
                sessions.remove(&id);
            }
        }
        Ok(report)
    }

    /// One continuous-batching step (see module docs for the pipeline).
    fn decode_step(
        &mut self,
        step: usize,
        admitted: Vec<Request>,
        sched: &mut Scheduler,
        sessions: &mut BTreeMap<u64, SeqSession>,
        opts: &DecodeOptions,
        rng: &mut Rng,
    ) -> Result<DecodeStepMetrics> {
        let step_start = Instant::now();
        let e = self.dims.n_experts;
        let n_layers = self.dims.n_layers;
        let top_k = self.dims.top_k;

        // Sessions for newly admitted requests (prompt capped at the
        // compiled prefill bucket).
        for req in &admitted {
            anyhow::ensure!(!req.tokens.is_empty(), "empty request {}", req.id);
            let mut tokens = req.tokens.clone();
            tokens.truncate(self.dims.seq_len);
            sessions.insert(
                req.id,
                SeqSession {
                    tokens,
                    kv: (0..n_layers).map(|_| None).collect(),
                },
            );
        }

        // Step workload in admission order: whole prompt for prefill
        // sequences, one row for decoding sequences.
        let workload: Vec<StepSeq> = sched
            .active()
            .iter()
            .map(|s| {
                let rows = match s.phase {
                    SeqPhase::Prefill => sessions[&s.id].tokens.len(),
                    _ => 1,
                };
                StepSeq {
                    id: s.id,
                    rows,
                    prefill: s.phase == SeqPhase::Prefill,
                }
            })
            .collect();

        let mut metrics = DecodeStepMetrics {
            step,
            n_seqs: workload.len(),
            worker_busy_s: vec![0.0; self.workers.len()],
            worker_slots: vec![0; self.workers.len()],
            ..Default::default()
        };

        // ---- 1. embed ---------------------------------------------------
        let t0 = Instant::now();
        let mut hidden: Vec<HostTensor> = Vec::with_capacity(workload.len());
        for ws in &workload {
            let sess = &sessions[&ws.id];
            let ids: Vec<i32> = if ws.prefill {
                sess.tokens.iter().map(|&t| t as i32).collect()
            } else {
                vec![*sess.tokens.last().expect("non-empty session") as i32]
            };
            let n = ids.len();
            let ids = IntTensor::new(ids, vec![1, n]);
            let x0 = self
                .leader
                .call("embed", &[In::I(&ids), In::W("embed")])?
                .remove(0);
            hidden.push(x0);
            if ws.prefill {
                metrics.n_prefill_tokens += n;
            } else {
                metrics.n_decode_tokens += 1;
            }
        }
        metrics.embed_s = t0.elapsed().as_secs_f64();

        // ---- 2. predict + plan ------------------------------------------
        // DOP follows the replan cadence; TEP is re-priced every step
        // (its prediction covers exactly this step's new tokens — ADR 001).
        let t0 = Instant::now();
        let total_slots: usize = workload.iter().map(|w| w.rows * top_k).sum();
        let plans: Vec<LayerPlan> = match self.strategy {
            ServeStrategy::NoPrediction => {
                (0..n_layers).map(|_| self.placement.static_plan()).collect()
            }
            ServeStrategy::DistributionOnly => {
                metrics.replanned = self.placement.replans_at(step);
                self.placement.decode_plans(step, total_slots)
            }
            ServeStrategy::TokenToExpert => {
                metrics.replanned = true;
                let n_real: Vec<usize> = workload.iter().map(|w| w.rows).collect();
                let counts = self.predict_counts(&hidden, &n_real)?;
                counts
                    .iter()
                    .map(|c| self.placement.plan_from_counts(c))
                    .collect()
            }
        };
        metrics.predictor_s = t0.elapsed().as_secs_f64();
        metrics.replicas_added = plans.iter().map(|p| p.added.len()).sum();

        // ---- 3. per-layer pipeline --------------------------------------
        let mut skews: Vec<f64> = Vec::new();
        for layer in 0..n_layers {
            let attn_names = attn_weight_names(layer);

            // Attention: full-sequence for prefill rows (seeding the KV
            // cache), incremental over the cache for decode rows.
            let t0 = Instant::now();
            for (i, ws) in workload.iter().enumerate() {
                let sess = sessions.get_mut(&ws.id).expect("session exists");
                if ws.prefill {
                    let mut out = self.leader.call(
                        "attention_prefill",
                        &[
                            In::T(&hidden[i]),
                            In::W(&attn_names[0]),
                            In::W(&attn_names[1]),
                            In::W(&attn_names[2]),
                            In::W(&attn_names[3]),
                            In::W(&attn_names[4]),
                        ],
                    )?;
                    let v = out.remove(2);
                    let k = out.remove(1);
                    hidden[i] = out.remove(0);
                    sess.kv[layer] = Some((k, v));
                } else {
                    let (k_cache, v_cache) =
                        sess.kv[layer].as_ref().expect("decode sequence has KV");
                    let mut out = self.leader.call(
                        "attention_step",
                        &[
                            In::T(&hidden[i]),
                            In::T(k_cache),
                            In::T(v_cache),
                            In::W(&attn_names[0]),
                            In::W(&attn_names[1]),
                            In::W(&attn_names[2]),
                            In::W(&attn_names[3]),
                            In::W(&attn_names[4]),
                        ],
                    )?;
                    let v_new = out.remove(2);
                    let k_new = out.remove(1);
                    hidden[i] = out.remove(0);
                    let (k_cache, v_cache) =
                        sess.kv[layer].as_mut().expect("decode sequence has KV");
                    k_cache.append_rows(&k_new);
                    v_cache.append_rows(&v_new);
                }
            }
            metrics.attention_s += t0.elapsed().as_secs_f64();

            // Router + top-k.
            let t0 = Instant::now();
            let ln = format!("layers.{layer}.moe.ln");
            let wr = format!("layers.{layer}.moe.router");
            let mut normed: Vec<HostTensor> = Vec::with_capacity(workload.len());
            let mut slots: Vec<Slot> = Vec::new();
            for (i, ws) in workload.iter().enumerate() {
                let mut out = self
                    .leader
                    .call("router", &[In::T(&hidden[i]), In::W(&ln), In::W(&wr)])?;
                let logits = out.remove(1);
                let xn = out.remove(0);
                slots.extend(route_sequence(i, &logits.data, e, ws.rows, top_k));
                normed.push(xn);
            }
            let actual_counts = expert_counts(&slots, e);
            skews.push(stats::skewness_of_counts(&actual_counts));
            metrics.n_slots += slots.len();
            metrics.router_s += t0.elapsed().as_secs_f64();

            // Dispatch + expert FFN + combine (shared with prefill).
            let outcome = self.ffn_phase(layer, &plans[layer], &slots, &normed, &mut hidden)?;
            for (w, &b) in outcome.worker_busy_s.iter().enumerate() {
                metrics.worker_busy_s[w] += b;
            }
            for (w, &s) in outcome.worker_slots.iter().enumerate() {
                metrics.worker_slots[w] += s;
            }
            metrics.upload_bytes += outcome.upload_bytes;
            metrics.ffn_wall_s += outcome.wall_s;

            // Per-step moving-average estimator update (§3.2.1: decode
            // steps keep teaching DOP while it serves).
            self.placement.observe(layer, &actual_counts);
        }

        // ---- 4. lm head + sampling --------------------------------------
        let t0 = Instant::now();
        for (i, ws) in workload.iter().enumerate() {
            let last = hidden[i].gather_rows(&[ws.rows - 1]);
            let logits = self
                .leader
                .call("lm_head", &[In::T(&last), In::W("final.ln"), In::W("embed")])?
                .remove(0);
            let token = sample_token(&logits.data, opts.temperature, rng);
            sessions
                .get_mut(&ws.id)
                .expect("session exists")
                .tokens
                .push(token);
            sched.record_token(ws.id);
        }
        metrics.lm_head_s = t0.elapsed().as_secs_f64();

        metrics.routing_skew = stats::mean(&skews);
        metrics.total_s = step_start.elapsed().as_secs_f64();
        Ok(metrics)
    }

    /// Run the AOT Token-to-Expert predictor on every sequence's
    /// embeddings (§3.1: before attention) and count predicted slots per
    /// (layer, expert). `hidden[i]` holds `≥ n_real[i]` embedded rows.
    fn predict_counts(
        &mut self,
        hidden: &[HostTensor],
        n_real: &[usize],
    ) -> Result<Vec<Vec<usize>>> {
        let e = self.dims.n_experts;
        let mut counts = vec![vec![0usize; e]; self.dims.n_layers];
        let head_names: Vec<String> = (0..self.dims.n_layers)
            .map(|l| format!("predictor.head.{l}"))
            .collect();
        for (seq, &n) in hidden.iter().zip(n_real) {
            let s_rows = seq.rows();
            let mut ins: Vec<In<'_>> = vec![
                In::T(seq),
                In::W("predictor.w1"),
                In::W("predictor.b1"),
            ];
            for name in &head_names {
                ins.push(In::W(name));
            }
            let logits = self.leader.call("predictor", &ins)?.remove(0);
            // logits [L, S, E]: argmax per (layer, real token).
            for l in 0..self.dims.n_layers {
                for t in 0..n.min(s_rows) {
                    let base = (l * s_rows + t) * e;
                    let row = &logits.data[base..base + e];
                    let arg = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    // Each token occupies top_k slots; scale the predicted
                    // count accordingly.
                    counts[l][arg] += self.dims.top_k;
                }
            }
        }
        Ok(counts)
    }

    /// Dispatch routed slots to the virtual-GPU workers under `plan`, run
    /// the expert FFNs, and combine `gate * expert_out` into `hidden`.
    /// Shared by prefill rounds and decode steps.
    fn ffn_phase(
        &mut self,
        layer: usize,
        plan: &LayerPlan,
        slots: &[Slot],
        normed: &[HostTensor],
        hidden: &mut [HostTensor],
    ) -> Result<FfnPhaseOutcome> {
        let d = self.dims.d_model;
        let mut outcome = FfnPhaseOutcome {
            wall_s: 0.0,
            worker_busy_s: vec![0.0; self.workers.len()],
            worker_slots: vec![0; self.workers.len()],
            upload_bytes: 0,
        };
        if slots.is_empty() {
            return Ok(outcome);
        }

        let experts: Vec<u8> = slots.iter().map(|s| s.expert).collect();
        let (assignment, _loads) = if plan.share.is_empty() {
            dispatch_tokens(&experts, &plan.placement)
        } else {
            dispatch_with_quota(&experts, &plan.placement, &plan.share)
        };

        // Group slots per (worker, expert), gather activations, run.
        let t0 = Instant::now();
        let mut groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
        for (slot_idx, (&slot_worker, slot)) in assignment.iter().zip(slots).enumerate() {
            groups
                .entry((slot_worker as usize, slot.expert as usize))
                .or_default()
                .push(slot_idx);
        }
        // §Perf: merge runt groups. Splitting an expert across workers
        // for a handful of slots costs a whole padded-bucket FFN call
        // (and possibly a weight transfer) for negligible balance gain;
        // fold any group smaller than MIN_GROUP into the largest group
        // of the same expert.
        const MIN_GROUP: usize = 16;
        let expert_ids: Vec<usize> = groups
            .keys()
            .map(|&(_, e)| e)
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect();
        for expert in expert_ids {
            let mut keys: Vec<(usize, usize)> = groups
                .keys()
                .filter(|&&(_, ge)| ge == expert)
                .cloned()
                .collect();
            if keys.len() < 2 {
                continue;
            }
            keys.sort_by_key(|k| groups[k].len());
            let biggest = *keys.last().unwrap();
            for key in &keys[..keys.len() - 1] {
                if groups[key].len() < MIN_GROUP {
                    let moved = groups.remove(key).unwrap();
                    groups.get_mut(&biggest).unwrap().extend(moved);
                }
            }
        }
        // §Perf (decode serving): greedy LPT placement of merged groups.
        // The dispatcher's slot-level least-loaded choice ignores bucket
        // padding — a 3-slot and a 14-slot group cost the same padded FFN
        // call, and on decode-scale batches the padded call count per
        // worker IS the critical path. Re-assign each group to the least-
        // loaded worker hosting a replica (largest group first, load
        // measured in padded rows; ties prefer the original worker, whose
        // weights are more likely resident). Without replicas (baseline)
        // every expert has one host and this is the identity.
        let mut items: Vec<((usize, usize), Vec<usize>)> = groups.into_iter().collect();
        items.sort_by_key(|(key, v)| (std::cmp::Reverse(v.len()), *key));
        let mut lpt_load = vec![0usize; self.workers.len()];
        let mut placed: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
        for ((orig_worker, expert), slot_indices) in items {
            let padded: usize =
                crate::runtime::bucket::split_into_buckets(&self.buckets, slot_indices.len())
                    .iter()
                    .map(|&(_, b)| b)
                    .sum();
            let hosts = plan.placement.gpus_of(expert);
            let target = hosts
                .iter()
                .copied()
                .min_by_key(|&g| (lpt_load[g], (g != orig_worker) as usize, g))
                .unwrap_or(orig_worker);
            lpt_load[target] += padded;
            placed.entry((target, expert)).or_default().extend(slot_indices);
        }

        let (reply_tx, reply_rx) = mpsc::channel::<WorkerResult>();
        let mut outstanding = 0usize;
        // Slot-order metadata for combining.
        let mut group_slots: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
        let mut msg_tag = 0u64;
        for ((worker, expert), slot_indices) in &placed {
            // Gather the normed activations for these slots.
            let mut data = Vec::with_capacity(slot_indices.len() * d);
            for &si in slot_indices {
                let slot = &slots[si];
                data.extend_from_slice(&normed[slot.seq_idx].row(slot.token_idx));
            }
            let xn = HostTensor::new(data, vec![slot_indices.len(), d]);
            // Oversized groups split across bucket-sized chunks.
            let mut offset = 0usize;
            for (chunk, _bucket) in
                crate::runtime::bucket::split_into_buckets(&self.buckets, xn.rows())
            {
                let rows: Vec<usize> = (offset..offset + chunk).collect();
                let tile = pad_to_bucket(xn.gather_rows(&rows), &self.buckets);
                msg_tag += 1;
                group_slots.insert(msg_tag, slot_indices[offset..offset + chunk].to_vec());
                self.workers[*worker].send(WorkerMsg::Run {
                    tag: msg_tag,
                    layer,
                    expert: *expert,
                    xn: tile,
                    n_real: chunk,
                    reply: reply_tx.clone(),
                });
                outstanding += 1;
                outcome.worker_slots[*worker] += chunk;
                offset += chunk;
            }
        }
        drop(reply_tx);

        // Combine: h += gate * expert_out at each slot.
        let mut received = 0usize;
        while received < outstanding {
            let result = reply_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("worker channel closed"))?;
            received += 1;
            if let Some(err) = &result.error {
                anyhow::bail!("worker {} failed: {err}", result.worker);
            }
            outcome.worker_busy_s[result.worker] += result.exec_s;
            outcome.upload_bytes += result.upload_bytes;
            let slot_indices = &group_slots[&result.tag];
            debug_assert_eq!(result.n_real, slot_indices.len());
            for (row, &si) in slot_indices.iter().enumerate() {
                let slot = &slots[si];
                let out_row = &result.out[row * d..(row + 1) * d];
                let h = &mut hidden[slot.seq_idx];
                let dst = &mut h.data[slot.token_idx * d..(slot.token_idx + 1) * d];
                for (a, &b) in dst.iter_mut().zip(out_row) {
                    *a += slot.gate * b;
                }
            }
        }
        outcome.wall_s = t0.elapsed().as_secs_f64();
        Ok(outcome)
    }
}

fn attn_weight_names(layer: usize) -> [String; 5] {
    [
        format!("layers.{layer}.attn.ln"),
        format!("layers.{layer}.attn.wq"),
        format!("layers.{layer}.attn.wk"),
        format!("layers.{layer}.attn.wv"),
        format!("layers.{layer}.attn.wo"),
    ]
}

/// Sample the next token from lm-head logits: greedy when `temperature <=
/// 0`, else softmax sampling at the given temperature (deterministic given
/// the run's seeded RNG).
fn sample_token(logits: &[f32], temperature: f64, rng: &mut Rng) -> u32 {
    debug_assert!(!logits.is_empty());
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u32;
    }
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let probs: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - max) as f64) / temperature).exp())
        .collect();
    rng.categorical(&probs) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_token_greedy_is_argmax() {
        let mut rng = Rng::new(1);
        let logits = [0.1f32, 3.0, -2.0, 1.0];
        assert_eq!(sample_token(&logits, 0.0, &mut rng), 1);
    }

    #[test]
    fn sample_token_tracks_distribution() {
        let mut rng = Rng::new(2);
        // One dominant logit: sampling should pick it most of the time.
        let logits = [0.0f32, 5.0, 0.0, 0.0];
        let hits = (0..200)
            .filter(|_| sample_token(&logits, 1.0, &mut rng) == 1)
            .count();
        assert!(hits > 150, "hits={hits}");
    }

    #[test]
    fn decode_options_defaults_sane() {
        let opts = DecodeOptions::default();
        assert!(opts.max_active >= 1);
        assert!(opts.max_steps > 0);
        assert_eq!(opts.arrival_interval, 0);
    }
}
