//! Continuous-batching scheduler: per-step request admission and eviction
//! (the vLLM-style iteration-level lifecycle on top of [`Request`]).
//!
//! Each serving *step* decodes one token for every active sequence; newly
//! admitted sequences contribute their whole prompt to the same step (their
//! prefill), so steps naturally mix prefill and decode work. The scheduler
//! owns only the lifecycle bookkeeping — FIFO admission up to `max_active`,
//! generation budgets, and eviction of finished sequences — while the
//! coordinator owns the tensors (hidden states, KV caches). See
//! `docs/adr/001-decode-prediction-cadence.md` for why the prediction
//! machinery runs per step rather than per request.

use std::collections::VecDeque;

use super::request::Request;

/// Lifecycle phase of an active sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqPhase {
    /// Admitted this step; its prompt runs through the model this step.
    Prefill,
    /// Generating one token per step.
    Decode,
    /// Budget spent; will be evicted at the end of the step.
    Finished,
}

/// Scheduler-side state of one active sequence.
#[derive(Clone, Debug)]
pub struct ActiveSeq {
    pub id: u64,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub generated: usize,
    pub phase: SeqPhase,
    /// Step index at which the sequence was admitted.
    pub admitted_step: usize,
}

pub struct Scheduler {
    waiting: VecDeque<Request>,
    active: Vec<ActiveSeq>,
    pub max_active: usize,
    admitted_order: Vec<u64>,
    finished_order: Vec<u64>,
}

impl Scheduler {
    pub fn new(max_active: usize) -> Scheduler {
        assert!(max_active >= 1, "max_active must be at least 1");
        Scheduler {
            waiting: VecDeque::new(),
            active: Vec::new(),
            max_active,
            admitted_order: Vec::new(),
            finished_order: Vec::new(),
        }
    }

    /// Enqueue an arriving request.
    pub fn push(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// No work left: nothing waiting, nothing active.
    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.active.is_empty()
    }

    /// FIFO admission up to the free capacity. Returns the admitted
    /// requests — the caller runs their prefill as part of this step.
    /// Invariant: `active_len() <= max_active` always holds afterwards.
    pub fn admit(&mut self, step: usize) -> Vec<Request> {
        let mut admitted = Vec::new();
        while self.active.len() < self.max_active {
            let Some(req) = self.waiting.pop_front() else {
                break;
            };
            self.active.push(ActiveSeq {
                id: req.id,
                prompt_len: req.tokens.len(),
                max_new_tokens: req.max_new_tokens,
                generated: 0,
                phase: SeqPhase::Prefill,
                admitted_step: step,
            });
            self.admitted_order.push(req.id);
            admitted.push(req);
        }
        admitted
    }

    /// Active sequences in admission order (the step's workload order).
    pub fn active(&self) -> &[ActiveSeq] {
        &self.active
    }

    /// Record one generated token for a sequence; transitions Prefill →
    /// Decode, and → Finished once the budget is spent. Returns true when
    /// the sequence just finished.
    pub fn record_token(&mut self, id: u64) -> bool {
        let seq = self
            .active
            .iter_mut()
            .find(|s| s.id == id)
            .unwrap_or_else(|| panic!("record_token on unknown sequence {id}"));
        seq.generated += 1;
        if seq.generated >= seq.max_new_tokens.max(1) {
            seq.phase = SeqPhase::Finished;
            true
        } else {
            seq.phase = SeqPhase::Decode;
            false
        }
    }

    /// Evict finished sequences, freeing capacity for the next step's
    /// admission. Returns their ids (in admission order).
    pub fn evict_finished(&mut self) -> Vec<u64> {
        let mut evicted = Vec::new();
        self.active.retain(|s| {
            if s.phase == SeqPhase::Finished {
                evicted.push(s.id);
                false
            } else {
                true
            }
        });
        self.finished_order.extend(evicted.iter().copied());
        evicted
    }

    /// Requeue an active sequence (ADR 008): its step could not be
    /// served — the workers hosting its expert groups are gone — so it
    /// leaves the active set and rejoins the *front* of the waiting
    /// queue (it already waited its turn once). The caller rebuilds the
    /// request from its session state; the sequence is requeued, not
    /// lost.
    pub fn requeue(&mut self, req: Request) {
        self.active.retain(|s| s.id != req.id);
        self.waiting.push_front(req);
    }

    /// Drop an active sequence without requeueing (per-sequence fault:
    /// its session state is unrecoverable). Returns whether it was
    /// active.
    pub fn drop_active(&mut self, id: u64) -> bool {
        let before = self.active.len();
        self.active.retain(|s| s.id != id);
        self.active.len() != before
    }

    /// Ids currently waiting (admission order). Used for end-of-run
    /// lost-sequence accounting: admitted ∖ (finished ∪ waiting ∪
    /// active) must be empty.
    pub fn waiting_ids(&self) -> Vec<u64> {
        self.waiting.iter().map(|r| r.id).collect()
    }

    pub fn admitted_order(&self) -> &[u64] {
        &self.admitted_order
    }

    pub fn finished_order(&self) -> &[u64] {
        &self.finished_order
    }

    /// Upper bound on the token-slots one step can route: every decoding
    /// sequence contributes one row, every prefilling sequence its prompt,
    /// each row occupying `top_k` expert slots. The FFN dispatcher pads
    /// each (worker, expert) group to a compiled bucket, so this bound is
    /// what the bucket-padding invariant tests check against. Exact
    /// because the coordinator caps prompts at the compiled prefill bucket
    /// *before* scheduling, so `prompt_len` is what the step will route.
    pub fn step_slot_bound(&self, top_k: usize) -> usize {
        self.active
            .iter()
            .map(|s| match s.phase {
                SeqPhase::Prefill => s.prompt_len,
                _ => 1,
            })
            .sum::<usize>()
            * top_k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize, max_new: usize) -> Request {
        Request::new(id, vec![1; prompt]).with_max_new_tokens(max_new)
    }

    #[test]
    fn admits_fifo_up_to_capacity() {
        let mut s = Scheduler::new(2);
        for i in 0..4 {
            s.push(req(i, 4, 2));
        }
        let admitted = s.admit(0);
        assert_eq!(admitted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(s.active_len(), 2);
        assert_eq!(s.waiting_len(), 2);
        // Full: admitting again is a no-op.
        assert!(s.admit(1).is_empty());
    }

    #[test]
    fn eviction_frees_capacity_in_order() {
        let mut s = Scheduler::new(2);
        for i in 0..3 {
            s.push(req(i, 4, 1));
        }
        s.admit(0);
        // One token each: budget of 1 → both finish.
        assert!(s.record_token(0));
        assert!(s.record_token(1));
        assert_eq!(s.evict_finished(), vec![0, 1]);
        assert_eq!(s.active_len(), 0);
        let admitted = s.admit(1);
        assert_eq!(admitted[0].id, 2);
        assert_eq!(s.admitted_order(), &[0, 1, 2]);
        assert_eq!(s.finished_order(), &[0, 1]);
    }

    #[test]
    fn phases_progress_prefill_decode_finished() {
        let mut s = Scheduler::new(1);
        s.push(req(7, 3, 2));
        s.admit(0);
        assert_eq!(s.active()[0].phase, SeqPhase::Prefill);
        assert!(!s.record_token(7));
        assert_eq!(s.active()[0].phase, SeqPhase::Decode);
        assert!(s.record_token(7));
        assert_eq!(s.active()[0].phase, SeqPhase::Finished);
        assert_eq!(s.evict_finished(), vec![7]);
        assert!(s.is_idle());
    }

    #[test]
    fn zero_budget_finishes_after_first_token() {
        let mut s = Scheduler::new(1);
        s.push(req(1, 4, 0));
        s.admit(0);
        assert!(s.record_token(1), "prefill-only request finishes immediately");
    }

    #[test]
    fn requeue_rejoins_front_of_queue() {
        let mut s = Scheduler::new(2);
        for i in 0..3 {
            s.push(req(i, 4, 3));
        }
        s.admit(0);
        s.record_token(0);
        s.record_token(1);
        // Sequence 1 becomes unplaceable: back to the front of waiting.
        s.requeue(req(1, 4, 2));
        assert_eq!(s.active_len(), 1);
        assert_eq!(s.waiting_ids(), vec![1, 2]);
        // Next admission re-admits it before the never-served request.
        let readmitted = s.admit(1);
        assert_eq!(readmitted.len(), 1, "only one slot was free");
        assert_eq!(readmitted[0].id, 1);
        // Re-admission appears twice in admitted order; lost-sequence
        // accounting therefore works over unique ids.
        assert_eq!(s.admitted_order(), &[0, 1, 1]);
    }

    #[test]
    fn drop_active_removes_without_finishing() {
        let mut s = Scheduler::new(2);
        s.push(req(0, 4, 2));
        s.admit(0);
        assert!(s.drop_active(0));
        assert!(!s.drop_active(0));
        assert_eq!(s.active_len(), 0);
        assert!(s.finished_order().is_empty());
    }

    #[test]
    fn slot_bound_counts_prefill_and_decode_rows() {
        let mut s = Scheduler::new(4);
        s.push(req(0, 10, 4));
        s.push(req(1, 6, 4));
        s.admit(0);
        // Both in prefill: (10 + 6) * top_k.
        assert_eq!(s.step_slot_bound(2), 32);
        s.record_token(0);
        s.record_token(1);
        // Both decoding: 2 rows * top_k.
        assert_eq!(s.step_slot_bound(2), 4);
    }
}
