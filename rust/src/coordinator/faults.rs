//! Fault injection and worker health tracking (ADR 008).
//!
//! The duplication plan already keeps hot experts on several workers —
//! this module turns that redundancy into fault tolerance. A
//! [`FaultPlan`] is a deterministic script of worker misbehaviors
//! (`kill[:W]@N`, `delay[:W]@N[xMS]`, `drop[:W]@N`) parsed from
//! `serve --inject-faults` or the `MOE_GPS_FAULTS` env var and executed
//! *inside* `worker_main`, so the coordinator-side detection/failover
//! machinery is exercised end-to-end. With no plan installed the worker
//! loop takes the same path as before this module existed — serving
//! output stays bitwise identical.
//!
//! [`WorkerHealth`] is the coordinator-side registry: which workers are
//! alive, an EWMA of observed per-op latency that derives the reply
//! deadline, and the `--worker-timeout` override. The pipeline waits on
//! replies with `recv_timeout(deadline)` and escalates through
//! [`MAX_TIMEOUT_WAITS`] exponentially backed-off retries before
//! declaring the owners of the outstanding groups dead.
//!
//! ADR 010: each micro-batch's coalesced `RunBatch` slab is one countable
//! op, so a fault script triggers at the same op index whatever the
//! wavefront depth — and the wavefront's final collect reuses this exact
//! escalation ladder, with chunks that were still in flight on a dead
//! worker redispatched to survivors through the same failover path.

use anyhow::{anyhow, Result};

/// Timeout waits (with exponential backoff: d, 2d, 4d, …) the reply
/// collectors tolerate with zero progress before declaring the workers
/// owning the outstanding groups dead. Stragglers that reply within the
/// backoff window are retries, not deaths.
pub const MAX_TIMEOUT_WAITS: u32 = 3;

/// Floor for the derived reply deadline when no `--worker-timeout`
/// override is given.
const MIN_DEADLINE_S: f64 = 2.0;

/// Deadline multiplier over the EWMA per-op execution time. Generous on
/// purpose: a queue of ops ahead of ours all count against our wait.
const DEADLINE_OP_FACTOR: f64 = 256.0;

/// What an injected fault does to the worker when its trigger op comes
/// up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Worker thread exits before processing the op (hard crash).
    Kill,
    /// Worker sleeps this many milliseconds before processing the op
    /// (straggler).
    Delay(u64),
    /// Worker consumes the op without ever replying (lost reply).
    Drop,
}

#[derive(Clone, Copy, Debug)]
struct FaultEntry {
    worker: usize,
    /// 1-based index into the worker's countable ops (Run / Attention /
    /// Prewarm messages).
    op: u64,
    action: FaultAction,
}

/// A deterministic script of worker faults, parsed from
/// `--inject-faults SPEC` / `MOE_GPS_FAULTS`. Spec grammar: a
/// comma-separated list of `kind[:worker]@op` entries where `kind` is
/// `kill`, `delay` or `drop`, `worker` defaults to 0, and `op` is the
/// 1-based countable-op index on that worker. `delay` takes an optional
/// `xMS` suffix (`delay:1@4x250` — sleep 250 ms; default 100).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    entries: Vec<FaultEntry>,
}

impl FaultPlan {
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut entries = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (head, tail) = part.split_once('@').ok_or_else(|| {
                anyhow!("fault `{part}`: missing `@op` (expected kind[:worker]@op[xMS])")
            })?;
            let (kind, worker) = match head.split_once(':') {
                Some((k, w)) => (
                    k,
                    w.parse::<usize>()
                        .map_err(|_| anyhow!("fault `{part}`: bad worker index `{w}`"))?,
                ),
                None => (head, 0),
            };
            let (op_s, delay_ms) = match tail.split_once('x') {
                Some((o, m)) => (
                    o,
                    Some(
                        m.parse::<u64>()
                            .map_err(|_| anyhow!("fault `{part}`: bad delay ms `{m}`"))?,
                    ),
                ),
                None => (tail, None),
            };
            let op = op_s
                .parse::<u64>()
                .map_err(|_| anyhow!("fault `{part}`: bad op index `{op_s}`"))?;
            if op == 0 {
                return Err(anyhow!("fault `{part}`: op index is 1-based"));
            }
            let action = match kind {
                "kill" => FaultAction::Kill,
                "delay" => FaultAction::Delay(delay_ms.unwrap_or(100)),
                "drop" => FaultAction::Drop,
                other => {
                    return Err(anyhow!(
                        "fault `{part}`: unknown kind `{other}` (kill|delay|drop)"
                    ))
                }
            };
            if delay_ms.is_some() && !matches!(action, FaultAction::Delay(_)) {
                return Err(anyhow!("fault `{part}`: `xMS` only applies to delay"));
            }
            entries.push(FaultEntry { worker, op, action });
        }
        Ok(FaultPlan { entries })
    }

    /// The plan from `MOE_GPS_FAULTS`, if set and non-empty.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var("MOE_GPS_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => Ok(Some(FaultPlan::parse(&spec)?)),
            _ => Ok(None),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The slice of the plan one worker executes, ordered by trigger op.
    pub fn for_worker(&self, worker: usize) -> WorkerFaults {
        let mut entries: Vec<(u64, FaultAction)> = self
            .entries
            .iter()
            .filter(|e| e.worker == worker)
            .map(|e| (e.op, e.action))
            .collect();
        entries.sort_by_key(|&(op, _)| op);
        WorkerFaults { entries, next_op: 0 }
    }
}

/// The per-worker fault script, consumed inside `worker_main`. Empty for
/// every worker unless a plan was installed — and the empty script's
/// `on_op` is a no-op, preserving bitwise parity with uninjected runs.
#[derive(Clone, Debug, Default)]
pub struct WorkerFaults {
    entries: Vec<(u64, FaultAction)>,
    next_op: u64,
}

impl WorkerFaults {
    /// Advance the countable-op counter and return the action scheduled
    /// for this op, if any.
    pub fn on_op(&mut self) -> Option<FaultAction> {
        if self.entries.is_empty() {
            return None;
        }
        self.next_op += 1;
        let op = self.next_op;
        let idx = self.entries.iter().position(|&(o, _)| o == op)?;
        Some(self.entries.remove(idx).1)
    }
}

/// Coordinator-side worker liveness registry plus the reply-deadline
/// model: deadline = `--worker-timeout` override, else
/// `max(MIN_DEADLINE_S, DEADLINE_OP_FACTOR × EWMA(op exec seconds))`.
#[derive(Clone, Debug)]
pub struct WorkerHealth {
    alive: Vec<bool>,
    ewma_op_s: f64,
    timeout_override: Option<f64>,
    /// Cumulative deaths over the coordinator's lifetime (survives
    /// per-round metric resets).
    pub total_deaths: u64,
}

impl WorkerHealth {
    pub fn new(n_workers: usize) -> WorkerHealth {
        WorkerHealth {
            alive: vec![true; n_workers],
            ewma_op_s: 0.0,
            timeout_override: None,
            total_deaths: 0,
        }
    }

    pub fn set_timeout_override(&mut self, seconds: Option<f64>) {
        self.timeout_override = seconds.filter(|s| *s > 0.0);
    }

    pub fn is_alive(&self, worker: usize) -> bool {
        self.alive.get(worker).copied().unwrap_or(false)
    }

    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }

    pub fn n_workers(&self) -> usize {
        self.alive.len()
    }

    /// Mark a worker dead. Returns `true` the first time (so death
    /// side-effects — metric bump, residency reclaim, replan — run
    /// exactly once per worker).
    pub fn mark_dead(&mut self, worker: usize) -> bool {
        if !self.is_alive(worker) {
            return false;
        }
        self.alive[worker] = false;
        self.total_deaths += 1;
        true
    }

    /// Fold one observed op execution time into the latency EWMA.
    pub fn observe_op(&mut self, exec_s: f64) {
        if !(exec_s.is_finite() && exec_s >= 0.0) {
            return;
        }
        self.ewma_op_s = if self.ewma_op_s == 0.0 {
            exec_s
        } else {
            0.9 * self.ewma_op_s + 0.1 * exec_s
        };
    }

    /// The base reply deadline for one timeout wait.
    pub fn deadline(&self) -> std::time::Duration {
        let s = self
            .timeout_override
            .unwrap_or_else(|| (DEADLINE_OP_FACTOR * self.ewma_op_s).max(MIN_DEADLINE_S));
        std::time::Duration::from_secs_f64(s)
    }
}

/// Terminal degraded state: every worker is dead, so no group can be
/// placed anywhere. The vendored `anyhow` carries message chains, not
/// typed causes, so the decode loop recognizes this condition by its
/// sentinel message (via [`is_all_workers_dead`]) and requeues the
/// in-flight sequences instead of reporting them lost.
pub const ALL_WORKERS_DEAD: &str = "all workers dead: no alive worker can host expert groups";

pub fn all_workers_dead_err() -> anyhow::Error {
    anyhow!("{ALL_WORKERS_DEAD}")
}

pub fn is_all_workers_dead(err: &anyhow::Error) -> bool {
    err.chain().any(|m| m == ALL_WORKERS_DEAD)
}

/// Per-sequence invariant violation (missing session, missing KV): the
/// serve loop evicts the offending sequence and keeps serving the rest
/// instead of aborting the process. Same sentinel-message scheme as
/// [`ALL_WORKERS_DEAD`].
const SEQ_FAULT_PREFIX: &str = "sequence fault #";

pub fn sequence_fault_err(id: u64, what: &str) -> anyhow::Error {
    anyhow!("{SEQ_FAULT_PREFIX}{id}: {what}")
}

/// The sequence id a [`sequence_fault_err`] error carries, if any.
pub fn sequence_fault_id(err: &anyhow::Error) -> Option<u64> {
    err.chain().find_map(|m| {
        let rest = m.strip_prefix(SEQ_FAULT_PREFIX)?;
        rest.split(':').next()?.trim().parse().ok()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let plan = FaultPlan::parse("kill:1@3, delay@2x250, drop:2@5, delay:3@7").unwrap();
        assert!(!plan.is_empty());
        let mut w1 = plan.for_worker(1);
        assert_eq!(w1.on_op(), None);
        assert_eq!(w1.on_op(), None);
        assert_eq!(w1.on_op(), Some(FaultAction::Kill));
        assert_eq!(w1.on_op(), None);
        let mut w0 = plan.for_worker(0);
        assert_eq!(w0.on_op(), None);
        assert_eq!(w0.on_op(), Some(FaultAction::Delay(250)));
        let mut w3 = plan.for_worker(3);
        for _ in 0..6 {
            assert_eq!(w3.on_op(), None);
        }
        assert_eq!(w3.on_op(), Some(FaultAction::Delay(100)), "default delay");
        let mut w2 = plan.for_worker(2);
        for _ in 0..4 {
            assert_eq!(w2.on_op(), None);
        }
        assert_eq!(w2.on_op(), Some(FaultAction::Drop));
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultPlan::parse("kill").is_err(), "missing @op");
        assert!(FaultPlan::parse("kill@0").is_err(), "op is 1-based");
        assert!(FaultPlan::parse("explode@3").is_err(), "unknown kind");
        assert!(FaultPlan::parse("kill:x@3").is_err(), "bad worker");
        assert!(FaultPlan::parse("delay@3xzz").is_err(), "bad delay ms");
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" , ").unwrap().is_empty());
    }

    #[test]
    fn empty_worker_faults_never_fire() {
        let mut f = WorkerFaults::default();
        for _ in 0..1000 {
            assert_eq!(f.on_op(), None);
        }
        assert_eq!(f.next_op, 0, "disabled path does not even count");
    }

    #[test]
    fn health_tracks_deaths_once() {
        let mut h = WorkerHealth::new(4);
        assert_eq!(h.alive_count(), 4);
        assert!(h.mark_dead(2));
        assert!(!h.mark_dead(2), "second death of same worker is a no-op");
        assert_eq!(h.alive_count(), 3);
        assert!(!h.is_alive(2));
        assert!(h.is_alive(0));
        assert_eq!(h.total_deaths, 1);
        assert!(!h.mark_dead(17), "out-of-range index tolerated");
    }

    #[test]
    fn sentinel_errors_survive_context_chains() {
        use anyhow::Context as _;
        let err = all_workers_dead_err();
        assert!(is_all_workers_dead(&err));
        let wrapped: anyhow::Error = Err::<(), _>(all_workers_dead_err())
            .context("decode step 3")
            .unwrap_err();
        assert!(is_all_workers_dead(&wrapped));
        assert!(!is_all_workers_dead(&anyhow!("boring failure")));

        let sf = sequence_fault_err(42, "session missing");
        assert_eq!(sequence_fault_id(&sf), Some(42));
        let sf2: anyhow::Error = Err::<(), _>(sequence_fault_err(7, "no KV"))
            .context("layer 1")
            .unwrap_err();
        assert_eq!(sequence_fault_id(&sf2), Some(7));
        assert_eq!(sequence_fault_id(&anyhow!("other")), None);
    }

    #[test]
    fn deadline_prefers_override_then_ewma_floor() {
        let mut h = WorkerHealth::new(2);
        assert_eq!(h.deadline(), std::time::Duration::from_secs_f64(2.0));
        for _ in 0..32 {
            h.observe_op(0.1); // 256 × 0.1 = 25.6 s ≫ floor
        }
        assert!(h.deadline() > std::time::Duration::from_secs(20));
        h.set_timeout_override(Some(0.05));
        assert_eq!(h.deadline(), std::time::Duration::from_secs_f64(0.05));
        h.set_timeout_override(None);
        assert!(h.deadline() > std::time::Duration::from_secs(20));
    }
}
