//! Model architecture configurations.
//!
//! The paper evaluates Mixtral 8×7B (main), Mixtral 8×22B (scaling
//! discussion), LLaMA-MoE (Appendix C / Figure 8) and Switch Transformer
//! (Appendix C / Figure 9). We also define the tiny MoE used by the real
//! serving driver (`examples/serve_moe.rs`), whose weights are generated and
//! AOT-compiled by `python/compile/aot.py`.

use crate::sim::hardware::Dtype;
use crate::util::json::Value;

/// Attention flavour (paper §5 "Generality across model architectures").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttentionKind {
    /// Multi-head attention (Switch Transformer).
    Mha,
    /// Grouped-query attention (Mixtral, LLaMA).
    Gqa,
    /// Multi-head latent attention (DeepSeek) — modelled via a KV
    /// compression rank.
    Mla,
}

/// FFN activation (paper §5: Mixtral/LLaMA SwiGLU, Switch ReLU).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FfnActivation {
    /// Gated SiLU: three weight matrices (gate, up, down).
    SwiGlu,
    /// Plain ReLU MLP: two weight matrices.
    Relu,
    /// Gated GELU: three matrices.
    GeGlu,
}

impl FfnActivation {
    /// Number of `d_model × d_ff`-sized weight matrices per expert.
    pub fn n_matrices(self) -> usize {
        match self {
            FfnActivation::SwiGlu | FfnActivation::GeGlu => 3,
            FfnActivation::Relu => 2,
        }
    }
}

/// One transformer layer's architecture (the simulator works per layer,
/// matching the paper's single-layer latency figures).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    /// Query heads.
    pub n_heads: usize,
    /// KV heads (== n_heads for MHA; fewer for GQA).
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// Expert hidden dimension.
    pub d_ff: usize,
    /// Number of experts per layer.
    pub n_experts: usize,
    /// Experts activated per token (top-k routing).
    pub top_k: usize,
    /// Number of transformer layers (full-model scaling; the per-layer
    /// simulator multiplies by this only when asked).
    pub n_layers: usize,
    /// Sliding-window size; `None` = full causal attention.
    pub sliding_window: Option<usize>,
    pub attention: AttentionKind,
    /// KV compression rank for MLA; ignored otherwise.
    pub mla_kv_rank: usize,
    pub activation: FfnActivation,
    pub vocab_size: usize,
    pub dtype: Dtype,
}

impl ModelConfig {
    /// Mixtral 8×7B [14]: d=4096, 32 q-heads / 8 kv-heads (GQA), head 128,
    /// d_ff=14336, 8 experts top-2, 32 layers, 4K sliding window, SwiGLU.
    pub fn mixtral_8x7b() -> ModelConfig {
        ModelConfig {
            name: "mixtral-8x7b".into(),
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            d_ff: 14336,
            n_experts: 8,
            top_k: 2,
            n_layers: 32,
            sliding_window: Some(4096),
            attention: AttentionKind::Gqa,
            mla_kv_rank: 0,
            activation: FfnActivation::SwiGlu,
            vocab_size: 32000,
            dtype: Dtype::Fp16,
        }
    }

    /// Mixtral 8×22B: d=6144, 48/8 heads, d_ff=16384, 56 layers.
    pub fn mixtral_8x22b() -> ModelConfig {
        ModelConfig {
            name: "mixtral-8x22b".into(),
            d_model: 6144,
            n_heads: 48,
            n_kv_heads: 8,
            head_dim: 128,
            d_ff: 16384,
            n_experts: 8,
            top_k: 2,
            n_layers: 56,
            sliding_window: None,
            attention: AttentionKind::Gqa,
            mla_kv_rank: 0,
            activation: FfnActivation::SwiGlu,
            vocab_size: 32768,
            dtype: Dtype::Fp16,
        }
    }

    /// LLaMA-MoE-3.5B [37] (Figure 8): LLaMA-2-7B re-sliced into 16 experts
    /// with top-4 routing, SwiGLU, no sliding window, MHA-style (32/32).
    pub fn llama_moe() -> ModelConfig {
        ModelConfig {
            name: "llama-moe-3.5b".into(),
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 32,
            head_dim: 128,
            d_ff: 2752, // 11008 / 16 * 4 — expert slices of the dense FFN
            n_experts: 16,
            top_k: 4,
            n_layers: 32,
            sliding_window: None,
            attention: AttentionKind::Gqa, // n_kv == n_heads → effectively MHA
            mla_kv_rank: 0,
            activation: FfnActivation::SwiGlu,
            vocab_size: 32000,
            dtype: Dtype::Fp16,
        }
    }

    /// Switch Transformer (base) [7] (Figure 9): d=768, 12 heads MHA,
    /// d_ff=3072 ReLU, 8 experts top-1 (switch routing), no GQA.
    pub fn switch_transformer() -> ModelConfig {
        ModelConfig {
            name: "switch-base-8".into(),
            d_model: 768,
            n_heads: 12,
            n_kv_heads: 12,
            head_dim: 64,
            d_ff: 3072,
            n_experts: 8,
            top_k: 1,
            n_layers: 12,
            sliding_window: None,
            attention: AttentionKind::Mha,
            mla_kv_rank: 0,
            activation: FfnActivation::Relu,
            vocab_size: 32128,
            dtype: Dtype::Fp16,
        }
    }

    /// DeepSeek-style MLA variant used by the §5 generality discussion.
    pub fn deepseek_like() -> ModelConfig {
        ModelConfig {
            name: "deepseek-like".into(),
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 32,
            head_dim: 128,
            d_ff: 1408,
            n_experts: 64,
            top_k: 6,
            n_layers: 27,
            sliding_window: None,
            attention: AttentionKind::Mla,
            mla_kv_rank: 512,
            activation: FfnActivation::SwiGlu,
            vocab_size: 102400,
            dtype: Dtype::Fp16,
        }
    }

    /// The tiny MoE actually served end-to-end by the coordinator
    /// (weights generated + AOT-compiled by `python/compile/aot.py`).
    /// Must stay in sync with `python/compile/model.py::TINY_CONFIG`.
    pub fn tiny_serve() -> ModelConfig {
        ModelConfig {
            name: "tiny-moe-serve".into(),
            d_model: 256,
            n_heads: 8,
            n_kv_heads: 2,
            head_dim: 32,
            d_ff: 512,
            n_experts: 8,
            top_k: 2,
            n_layers: 4,
            sliding_window: None,
            attention: AttentionKind::Gqa,
            mla_kv_rank: 0,
            activation: FfnActivation::SwiGlu,
            vocab_size: 4096,
            dtype: Dtype::Fp32,
        }
    }

    /// Look up a named preset.
    pub fn by_name(name: &str) -> anyhow::Result<ModelConfig> {
        match name {
            "mixtral-8x7b" | "mixtral" => Ok(Self::mixtral_8x7b()),
            "mixtral-8x22b" => Ok(Self::mixtral_8x22b()),
            "llama-moe" | "llama-moe-3.5b" => Ok(Self::llama_moe()),
            "switch" | "switch-base-8" | "switch-transformer" => {
                Ok(Self::switch_transformer())
            }
            "deepseek-like" => Ok(Self::deepseek_like()),
            "tiny" | "tiny-moe-serve" => Ok(Self::tiny_serve()),
            other => anyhow::bail!(
                "unknown model `{other}` (try mixtral-8x7b, mixtral-8x22b, \
                 llama-moe, switch, deepseek-like, tiny)"
            ),
        }
    }

    /// Bytes of one expert's weights (the unit moved by duplication).
    /// Mixtral 8×7B: 3 × 4096 × 14336 × 2 B ≈ 352 MB; the paper's §5
    /// back-of-envelope uses 2 matrices (`4096·14336·2·2`) ≈ 235 MB.
    pub fn expert_bytes(&self) -> f64 {
        self.activation.n_matrices() as f64
            * self.d_model as f64
            * self.d_ff as f64
            * self.dtype.bytes() as f64
    }

    /// Total parameter count of one layer (attention + all experts).
    pub fn layer_params(&self) -> f64 {
        let d = self.d_model as f64;
        let attn = match self.attention {
            AttentionKind::Mla => {
                // q proj + compressed kv projections + out proj (coarse).
                d * (self.n_heads * self.head_dim) as f64 * 2.0
                    + d * self.mla_kv_rank as f64 * 2.0
            }
            _ => {
                let q = d * (self.n_heads * self.head_dim) as f64;
                let kv = 2.0 * d * (self.n_kv_heads * self.head_dim) as f64;
                let o = (self.n_heads * self.head_dim) as f64 * d;
                q + kv + o
            }
        };
        attn + self.n_experts as f64 * self.expert_bytes() / self.dtype.bytes() as f64
            + d * self.n_experts as f64 // router
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("name", Value::Str(self.name.clone()))
            .set("d_model", Value::Num(self.d_model as f64))
            .set("n_heads", Value::Num(self.n_heads as f64))
            .set("n_kv_heads", Value::Num(self.n_kv_heads as f64))
            .set("head_dim", Value::Num(self.head_dim as f64))
            .set("d_ff", Value::Num(self.d_ff as f64))
            .set("n_experts", Value::Num(self.n_experts as f64))
            .set("top_k", Value::Num(self.top_k as f64))
            .set("n_layers", Value::Num(self.n_layers as f64))
            .set(
                "sliding_window",
                match self.sliding_window {
                    Some(w) => Value::Num(w as f64),
                    None => Value::Null,
                },
            )
            .set(
                "attention",
                Value::Str(
                    match self.attention {
                        AttentionKind::Mha => "mha",
                        AttentionKind::Gqa => "gqa",
                        AttentionKind::Mla => "mla",
                    }
                    .into(),
                ),
            )
            .set("mla_kv_rank", Value::Num(self.mla_kv_rank as f64))
            .set(
                "activation",
                Value::Str(
                    match self.activation {
                        FfnActivation::SwiGlu => "swiglu",
                        FfnActivation::Relu => "relu",
                        FfnActivation::GeGlu => "geglu",
                    }
                    .into(),
                ),
            )
            .set("vocab_size", Value::Num(self.vocab_size as f64))
            .set(
                "dtype",
                Value::Str(
                    match self.dtype {
                        Dtype::Fp16 => "fp16",
                        Dtype::Bf16 => "bf16",
                        Dtype::Fp32 => "fp32",
                    }
                    .into(),
                ),
            );
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<ModelConfig> {
        let attention = match v.req_str("attention")? {
            "mha" => AttentionKind::Mha,
            "gqa" => AttentionKind::Gqa,
            "mla" => AttentionKind::Mla,
            other => anyhow::bail!("unknown attention kind `{other}`"),
        };
        let activation = match v.req_str("activation")? {
            "swiglu" => FfnActivation::SwiGlu,
            "relu" => FfnActivation::Relu,
            "geglu" => FfnActivation::GeGlu,
            other => anyhow::bail!("unknown activation `{other}`"),
        };
        let dtype = match v.req_str("dtype")? {
            "fp16" => Dtype::Fp16,
            "bf16" => Dtype::Bf16,
            "fp32" => Dtype::Fp32,
            other => anyhow::bail!("unknown dtype `{other}`"),
        };
        Ok(ModelConfig {
            name: v.req_str("name")?.to_string(),
            d_model: v.req_usize("d_model")?,
            n_heads: v.req_usize("n_heads")?,
            n_kv_heads: v.req_usize("n_kv_heads")?,
            head_dim: v.req_usize("head_dim")?,
            d_ff: v.req_usize("d_ff")?,
            n_experts: v.req_usize("n_experts")?,
            top_k: v.req_usize("top_k")?,
            n_layers: v.req_usize("n_layers")?,
            sliding_window: match v.get("sliding_window") {
                Some(Value::Null) | None => None,
                Some(x) => Some(
                    x.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("bad sliding_window"))?,
                ),
            },
            attention,
            mla_kv_rank: v.req_usize("mla_kv_rank")?,
            activation,
            vocab_size: v.req_usize("vocab_size")?,
            dtype,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_by_name() {
        for name in [
            "mixtral-8x7b",
            "mixtral-8x22b",
            "llama-moe",
            "switch",
            "deepseek-like",
            "tiny",
        ] {
            let m = ModelConfig::by_name(name).unwrap();
            assert!(m.n_experts >= 8);
            assert!(m.top_k >= 1 && m.top_k <= m.n_experts);
        }
        assert!(ModelConfig::by_name("nope").is_err());
    }

    #[test]
    fn mixtral_expert_bytes_matches_paper_scale() {
        // Paper §5 counts 2 matrices: 4096*14336*2*2 ≈ 235 MB. With the
        // full 3-matrix SwiGLU expert we get 1.5×that ≈ 352 MB.
        let m = ModelConfig::mixtral_8x7b();
        let paper_two_matrices = 4096.0 * 14336.0 * 2.0 * 2.0;
        assert!((m.expert_bytes() / paper_two_matrices - 1.5).abs() < 1e-9);
    }

    #[test]
    fn switch_uses_two_matrices() {
        let m = ModelConfig::switch_transformer();
        assert_eq!(m.activation.n_matrices(), 2);
        assert_eq!(m.top_k, 1);
    }

    #[test]
    fn json_round_trip_all_presets() {
        for mk in [
            ModelConfig::mixtral_8x7b,
            ModelConfig::mixtral_8x22b,
            ModelConfig::llama_moe,
            ModelConfig::switch_transformer,
            ModelConfig::deepseek_like,
            ModelConfig::tiny_serve,
        ] {
            let m = mk();
            let text = m.to_json().to_string_pretty();
            let parsed =
                ModelConfig::from_json(&Value::parse(&text).unwrap()).unwrap();
            assert_eq!(m, parsed);
        }
    }

    #[test]
    fn layer_params_mixtral_magnitude() {
        // Mixtral 8x7B total params ≈ 46.7B over 32 layers → ~1.4B/layer.
        let m = ModelConfig::mixtral_8x7b();
        let p = m.layer_params();
        assert!(p > 1.0e9 && p < 2.0e9, "p={p}");
    }
}
