//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! A criterion-like runner used by every file in `rust/benches/` (which are
//! declared with `harness = false`): warmup, adaptive iteration count to hit
//! a target measurement time, and a summary with mean / median / p95 /
//! stddev. Also provides `black_box` to defeat constant folding.

use std::time::{Duration, Instant};

use crate::util::stats;

/// Prevent the optimizer from eliminating a value/computation.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Result summary of one benchmark.
#[derive(Clone, Debug)]
pub struct Summary {
    pub name: String,
    pub iterations: u64,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Summary {
    pub fn print(&self) {
        println!(
            "bench {:<48} {:>12}/iter  (median {:>12}, p95 {:>12}, ±{:>10}, n={})",
            self.name,
            crate::util::human_time(self.mean_s),
            crate::util::human_time(self.median_s),
            crate::util::human_time(self.p95_s),
            crate::util::human_time(self.stddev_s),
            self.iterations,
        );
    }
}

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_samples: 10,
            max_samples: 2000,
        }
    }
}

impl Bencher {
    /// Quick settings for CI-style smoke benches.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            min_samples: 5,
            max_samples: 200,
        }
    }

    /// Benchmark a closure. The closure should produce a value which the
    /// harness black-boxes (preventing dead-code elimination).
    pub fn bench<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Summary {
        // Warmup + estimate per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;

        // Choose batch size so each sample takes ≈ measure/min_samples but at
        // least 1 iteration.
        let target_sample_s =
            self.measure.as_secs_f64() / self.min_samples.max(1) as f64;
        let batch = ((target_sample_s / per_iter.max(1e-9)).round() as u64).clamp(1, 1 << 24);

        let mut samples: Vec<f64> = Vec::new();
        let run_start = Instant::now();
        let mut total_iters: u64 = 0;
        while (run_start.elapsed() < self.measure || samples.len() < self.min_samples)
            && samples.len() < self.max_samples
        {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            let dt = t0.elapsed().as_secs_f64() / batch as f64;
            samples.push(dt);
            total_iters += batch;
        }

        Summary {
            name: name.to_string(),
            iterations: total_iters,
            mean_s: stats::mean(&samples),
            median_s: stats::median(&samples),
            p95_s: stats::percentile(&samples, 95.0),
            stddev_s: stats::stddev(&samples),
            min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max_s: samples.iter().cloned().fold(0.0, f64::max),
        }
    }

    /// Benchmark and print immediately; returns the summary for further use.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, f: F) -> Summary {
        let s = self.bench(name, f);
        s.print();
        s
    }
}

/// Group header printer used by the bench binaries so `cargo bench` output
/// is organised per paper table/figure.
pub fn group(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_summary() {
        let b = Bencher::quick();
        let s = b.bench("noop_sum", || (0..100u64).sum::<u64>());
        assert!(s.iterations > 0);
        assert!(s.mean_s >= 0.0);
        assert!(s.min_s <= s.median_s);
        assert!(s.median_s <= s.max_s + 1e-12);
        assert!(s.p95_s >= s.median_s - 1e-12);
    }

    #[test]
    fn slower_closure_measures_slower() {
        let b = Bencher::quick();
        let fast = b.bench("fast", || black_box(1u64) + 1);
        let slow = b.bench("slow", || {
            let mut acc = 0u64;
            for i in 0..20_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert!(
            slow.median_s > fast.median_s * 5.0,
            "slow={} fast={}",
            slow.median_s,
            fast.median_s
        );
    }
}
