//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! A criterion-like runner used by every file in `rust/benches/` (which are
//! declared with `harness = false`): warmup, adaptive iteration count to hit
//! a target measurement time, and a summary with mean / median / p95 /
//! stddev. Also provides `black_box` to defeat constant folding.

use std::time::{Duration, Instant};

use crate::util::stats;

/// Prevent the optimizer from eliminating a value/computation.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Result summary of one benchmark.
#[derive(Clone, Debug)]
pub struct Summary {
    pub name: String,
    pub iterations: u64,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Summary {
    pub fn print(&self) {
        println!(
            "bench {:<48} {:>12}/iter  (median {:>12}, p95 {:>12}, ±{:>10}, n={})",
            self.name,
            crate::util::human_time(self.mean_s),
            crate::util::human_time(self.median_s),
            crate::util::human_time(self.p95_s),
            crate::util::human_time(self.stddev_s),
            self.iterations,
        );
    }
}

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_samples: 10,
            max_samples: 2000,
        }
    }
}

impl Bencher {
    /// Quick settings for CI-style smoke benches.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            min_samples: 5,
            max_samples: 200,
        }
    }

    /// Benchmark a closure. The closure should produce a value which the
    /// harness black-boxes (preventing dead-code elimination).
    pub fn bench<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Summary {
        // Warmup + estimate per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;

        // Choose batch size so each sample takes ≈ measure/min_samples but at
        // least 1 iteration.
        let target_sample_s =
            self.measure.as_secs_f64() / self.min_samples.max(1) as f64;
        let batch = ((target_sample_s / per_iter.max(1e-9)).round() as u64).clamp(1, 1 << 24);

        let mut samples: Vec<f64> = Vec::new();
        let run_start = Instant::now();
        let mut total_iters: u64 = 0;
        while (run_start.elapsed() < self.measure || samples.len() < self.min_samples)
            && samples.len() < self.max_samples
        {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            let dt = t0.elapsed().as_secs_f64() / batch as f64;
            samples.push(dt);
            total_iters += batch;
        }

        Summary {
            name: name.to_string(),
            iterations: total_iters,
            mean_s: stats::mean(&samples),
            median_s: stats::median(&samples),
            p95_s: stats::percentile(&samples, 95.0),
            stddev_s: stats::stddev(&samples),
            min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max_s: samples.iter().cloned().fold(0.0, f64::max),
        }
    }

    /// Benchmark and print immediately; returns the summary for further use.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, f: F) -> Summary {
        let s = self.bench(name, f);
        s.print();
        s
    }
}

/// Group header printer used by the bench binaries so `cargo bench` output
/// is organised per paper table/figure.
pub fn group(title: &str) {
    println!("\n=== {title} ===");
}

/// Machine-readable serving-bench results (`BENCH_serve.json`), so the
/// perf trajectory is tracked across PRs: each record carries the bench
/// name, prediction strategy, lookahead regime, steady-state tokens/sec,
/// and the hidden-vs-exposed duplication-transfer split (ADR 002).
/// Writers merge by (bench, strategy, lookahead), so `decode_serve` and
/// `pipeline_overlap` can share one file without clobbering each other.
pub mod emit {
    use std::path::{Path, PathBuf};

    use crate::util::json::Value;

    pub const DEFAULT_PATH: &str = "BENCH_serve.json";
    pub const SCHEMA: &str = "moe-gps/serve-bench/v1";

    /// One serving-bench measurement. Kernel benches (`benches/kernels.rs`,
    /// ADR 007) reuse the schema with `bench = "kernels/<op>/<shape>"`,
    /// `strategy` = the SIMD dispatch tier, `tokens_per_s` = elements/sec,
    /// and the optional `gflops`/`gbs` rates filled in.
    #[derive(Clone, Debug, Default, PartialEq)]
    pub struct ServeBenchRecord {
        pub bench: String,
        pub strategy: String,
        pub lookahead: bool,
        pub tokens_per_s: f64,
        /// Worker nanoseconds spent on overlapped duplication transfers.
        pub hidden_transfer_ns: f64,
        /// Leader nanoseconds stalled on duplication transfers.
        pub exposed_transfer_ns: f64,
        pub hidden_bytes: u64,
        pub exposed_bytes: u64,
        /// Arithmetic rate for kernel records (ADR 007); absent on
        /// serving records and on pre-ADR-007 files.
        pub gflops: Option<f64>,
        /// Memory-traffic rate for kernel records (ADR 007).
        pub gbs: Option<f64>,
        /// Leader→worker dispatch messages per served token (ADR 009) —
        /// the coalescing figure the zero-copy data plane optimises;
        /// absent on kernel records and pre-ADR-009 files.
        pub msgs_per_token: Option<f64>,
    }

    impl ServeBenchRecord {
        fn key(&self) -> (String, String, bool) {
            (self.bench.clone(), self.strategy.clone(), self.lookahead)
        }

        fn to_json(&self) -> Value {
            let mut v = Value::obj();
            v.set("bench", Value::Str(self.bench.clone()))
                .set("strategy", Value::Str(self.strategy.clone()))
                .set("lookahead", Value::Bool(self.lookahead))
                .set("tokens_per_s", Value::Num(self.tokens_per_s))
                .set("hidden_transfer_ns", Value::Num(self.hidden_transfer_ns))
                .set("exposed_transfer_ns", Value::Num(self.exposed_transfer_ns))
                .set("hidden_bytes", Value::Num(self.hidden_bytes as f64))
                .set("exposed_bytes", Value::Num(self.exposed_bytes as f64));
            if let Some(g) = self.gflops {
                v.set("gflops", Value::Num(g));
            }
            if let Some(g) = self.gbs {
                v.set("gbs", Value::Num(g));
            }
            if let Some(m) = self.msgs_per_token {
                v.set("msgs_per_token", Value::Num(m));
            }
            v
        }

        fn from_json(v: &Value) -> Option<ServeBenchRecord> {
            Some(ServeBenchRecord {
                bench: v.get("bench")?.as_str()?.to_string(),
                strategy: v.get("strategy")?.as_str()?.to_string(),
                lookahead: v.get("lookahead")?.as_bool()?,
                tokens_per_s: v.get("tokens_per_s")?.as_f64()?,
                hidden_transfer_ns: v.get("hidden_transfer_ns")?.as_f64()?,
                exposed_transfer_ns: v.get("exposed_transfer_ns")?.as_f64()?,
                hidden_bytes: v.get("hidden_bytes")?.as_f64()? as u64,
                exposed_bytes: v.get("exposed_bytes")?.as_f64()? as u64,
                // Kernel-rate fields are optional: pre-ADR-007 records
                // simply lack them.
                gflops: v.get("gflops").and_then(Value::as_f64),
                gbs: v.get("gbs").and_then(Value::as_f64),
                // Absent on kernel records and pre-ADR-009 files.
                msgs_per_token: v.get("msgs_per_token").and_then(Value::as_f64),
            })
        }
    }

    /// Where the serving benches write their results: `$BENCH_SERVE_JSON`
    /// or `BENCH_serve.json` in the working directory (`rust/` under
    /// `cargo bench`).
    pub fn bench_json_path() -> PathBuf {
        std::env::var("BENCH_SERVE_JSON")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(DEFAULT_PATH))
    }

    /// Read the records currently on disk (empty on a missing or
    /// unparseable file — the trajectory starts fresh rather than erroring).
    pub fn read_serve_benches(path: &Path) -> Vec<ServeBenchRecord> {
        let Ok(text) = std::fs::read_to_string(path) else {
            return Vec::new();
        };
        let Ok(v) = Value::parse(&text) else {
            return Vec::new();
        };
        v.get("results")
            .and_then(Value::as_arr)
            .map(|arr| arr.iter().filter_map(ServeBenchRecord::from_json).collect())
            .unwrap_or_default()
    }

    /// Validate a serve-bench trajectory file against the
    /// `moe-gps/serve-bench/v1` schema (the CI bench-smoke gate:
    /// `moe-gps bench-validate`). Checks the schema tag, that every
    /// record parses, and that throughputs are finite and non-negative.
    /// With `require_results`, an empty `results` array is an error.
    /// Returns the number of valid records.
    pub fn validate_serve_benches(
        path: &Path,
        require_results: bool,
    ) -> anyhow::Result<usize> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
        let v = Value::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: invalid JSON: {e}", path.display()))?;
        let schema = v
            .get("schema")
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing `schema` field"))?;
        anyhow::ensure!(
            schema == SCHEMA,
            "schema mismatch: got `{schema}`, want `{SCHEMA}`"
        );
        let results = v
            .get("results")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing `results` array"))?;
        for (i, r) in results.iter().enumerate() {
            let rec = ServeBenchRecord::from_json(r)
                .ok_or_else(|| anyhow::anyhow!("record {i} is malformed"))?;
            anyhow::ensure!(
                rec.tokens_per_s.is_finite() && rec.tokens_per_s >= 0.0,
                "record {i} ({}) has invalid tokens_per_s {}",
                rec.bench,
                rec.tokens_per_s
            );
        }
        anyhow::ensure!(
            !require_results || !results.is_empty(),
            "`results` is empty but records were required (run the serve \
             benches first: cargo bench --bench serve_hotpath)"
        );
        Ok(results.len())
    }

    /// Forecast-accuracy regression gate (ADR 006): read a serve report
    /// (`serve --horizon H --report F.json`) and assert its realized
    /// forecast L1 (`forecast_l1` — the layer-weighted mean L1 distance
    /// between forecast and realized expert shares) is present and at
    /// most `max_l1`. A null or missing field means no forecasts matured
    /// (horizon 0, or too short a run) and is an error — the gate must
    /// measure something. Returns the measured value.
    pub fn validate_forecast_error(path: &Path, max_l1: f64) -> anyhow::Result<f64> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
        let v = Value::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: invalid JSON: {e}", path.display()))?;
        let l1 = v
            .get("forecast_l1")
            .and_then(Value::as_f64)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "{}: no realized forecast error (`forecast_l1` missing or \
                     null — serve with --horizon > 0 and enough replan windows)",
                    path.display()
                )
            })?;
        anyhow::ensure!(
            l1.is_finite() && l1 >= 0.0,
            "{}: invalid forecast_l1 {l1}",
            path.display()
        );
        anyhow::ensure!(
            l1 <= max_l1,
            "realized forecast L1 {l1:.4} exceeds bound {max_l1} (the load \
             forecaster regressed or the trace is adversarial)"
        );
        Ok(l1)
    }

    /// Chaos gate (ADR 008): reads a fault-injected serve report (`serve
    /// --inject-faults … --report F.json`) and asserts the injection
    /// actually bit (at least one worker death) AND no sequence was lost
    /// — every admitted sequence finished, was requeued, or was
    /// explicitly evicted. Returns (worker_deaths, lost_seqs).
    pub fn validate_chaos_report(path: &Path) -> anyhow::Result<(u64, u64)> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
        let v = Value::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: invalid JSON: {e}", path.display()))?;
        let field = |name: &str| -> anyhow::Result<u64> {
            v.get(name)
                .and_then(Value::as_f64)
                .map(|x| x as u64)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "{}: `{name}` missing — not a fault-aware serve \
                         report (serve with --report on this build)",
                        path.display()
                    )
                })
        };
        let deaths = field("worker_deaths")?;
        let lost = field("lost_seqs")?;
        anyhow::ensure!(
            deaths >= 1,
            "{}: no worker death recorded — the fault injection never \
             fired (check the --inject-faults spec against the run length)",
            path.display()
        );
        anyhow::ensure!(
            lost == 0,
            "{}: {lost} sequence(s) lost under faults — failover must \
             finish, requeue, or explicitly evict every admitted sequence",
            path.display()
        );
        Ok((deaths, lost))
    }

    /// Copy-accounting gate (ADR 009): reads a serve report and asserts
    /// the data plane's deep-copied fraction — bytes_copied /
    /// (bytes_copied + bytes_shared) — is at most `max_frac`. Missing
    /// keys mean a pre-ADR-009 report and are an error (the gate must
    /// measure something); a plane that moved zero bytes passes with
    /// fraction 0. Returns the measured fraction.
    pub fn validate_copied_frac(path: &Path, max_frac: f64) -> anyhow::Result<f64> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
        let v = Value::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: invalid JSON: {e}", path.display()))?;
        let field = |name: &str| -> anyhow::Result<f64> {
            v.get(name).and_then(Value::as_f64).ok_or_else(|| {
                anyhow::anyhow!(
                    "{}: `{name}` missing — not a copy-accounting serve \
                     report (serve with --report on this build)",
                    path.display()
                )
            })
        };
        let copied = field("bytes_copied")?;
        let shared = field("bytes_shared")?;
        anyhow::ensure!(
            copied.is_finite() && copied >= 0.0 && shared.is_finite() && shared >= 0.0,
            "{}: invalid copy accounting (copied={copied}, shared={shared})",
            path.display()
        );
        let total = copied + shared;
        let frac = if total > 0.0 { copied / total } else { 0.0 };
        anyhow::ensure!(
            frac <= max_frac,
            "{}: data plane copied fraction {frac:.4} exceeds bound {max_frac} \
             — a zero-copy path regressed to deep copies (ADR 009)",
            path.display()
        );
        Ok(frac)
    }

    /// Wavefront-occupancy gate (ADR 010): reads a serve report written
    /// by `serve --microbatch K --report F.json` and asserts the
    /// window-weighted worker idle fraction (`worker_idle_frac`) is at
    /// most `max_idle_frac`. Missing keys mean a pre-ADR-010 report and
    /// are an error (the gate must measure something); a finite fraction
    /// outside [0, 1] is a measurement bug and fails too. Returns
    /// (worker_idle_frac, leader_stall_s).
    pub fn validate_wavefront_report(
        path: &Path,
        max_idle_frac: f64,
    ) -> anyhow::Result<(f64, f64)> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
        let v = Value::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: invalid JSON: {e}", path.display()))?;
        let field = |name: &str| -> anyhow::Result<f64> {
            v.get(name).and_then(Value::as_f64).ok_or_else(|| {
                anyhow::anyhow!(
                    "{}: `{name}` missing — not a wavefront-aware serve \
                     report (serve with --report on this build)",
                    path.display()
                )
            })
        };
        let idle = field("worker_idle_frac")?;
        let stall = field("leader_stall_s")?;
        anyhow::ensure!(
            idle.is_finite() && (0.0..=1.0).contains(&idle),
            "{}: invalid worker_idle_frac {idle} (must be a fraction in [0, 1])",
            path.display()
        );
        anyhow::ensure!(
            stall.is_finite() && stall >= 0.0,
            "{}: invalid leader_stall_s {stall}",
            path.display()
        );
        anyhow::ensure!(
            idle <= max_idle_frac,
            "{}: worker idle fraction {idle:.4} exceeds bound {max_idle_frac} \
             — workers are starving through router/combine stalls (ADR 010)",
            path.display()
        );
        Ok((idle, stall))
    }

    /// Kernel-speedup gate (ADR 007): for every `kernels/…dot…` or
    /// `kernels/…matmul…` bench that recorded BOTH a `scalar` record and a
    /// vector-tier record (`avx2+fma` / `neon`), assert the vector tier is
    /// at least `min_speedup`× the scalar rate. When the file holds kernel
    /// records but *no* vector-tier ones (the machine has no vector ISA,
    /// or `MOE_GPS_SIMD=scalar` forced the portable path), that is
    /// reported loudly via the returned message rather than silently
    /// passed. Errors when no kernel records exist at all.
    /// Returns (comparisons checked, human summary).
    pub fn validate_kernel_speedups(
        path: &Path,
        min_speedup: f64,
    ) -> anyhow::Result<(usize, String)> {
        let records = read_serve_benches(path);
        let kernels: Vec<&ServeBenchRecord> = records
            .iter()
            .filter(|r| r.bench.starts_with("kernels/"))
            .collect();
        anyhow::ensure!(
            !kernels.is_empty(),
            "{}: no kernel records (run: cargo bench --bench kernels)",
            path.display()
        );
        let has_vector = kernels.iter().any(|r| r.strategy != "scalar");
        if !has_vector {
            return Ok((
                0,
                format!(
                    "forced-scalar dispatch recorded ({} kernel record(s), no \
                     vector ISA tier) — speedup gate not applicable",
                    kernels.len()
                ),
            ));
        }
        let mut checked = 0usize;
        for r in &kernels {
            if r.strategy == "scalar"
                || !(r.bench.contains("dot") || r.bench.contains("matmul"))
            {
                continue;
            }
            let Some(scalar) = kernels
                .iter()
                .find(|s| s.bench == r.bench && s.strategy == "scalar")
            else {
                continue;
            };
            let speedup = r.tokens_per_s / scalar.tokens_per_s.max(f64::MIN_POSITIVE);
            anyhow::ensure!(
                speedup >= min_speedup,
                "{}: {} tier `{}` is only {speedup:.2}× scalar (bound {min_speedup}×)",
                path.display(),
                r.bench,
                r.strategy
            );
            checked += 1;
        }
        anyhow::ensure!(
            checked > 0,
            "{}: vector-tier kernel records exist but none pair a scalar \
             dot/matmul baseline — re-run the kernels bench",
            path.display()
        );
        Ok((
            checked,
            format!("{checked} dot/matmul kernel(s) ≥ {min_speedup}× scalar"),
        ))
    }

    /// Stored-baseline regression gate: compare each `serve_hotpath`
    /// record in `path` against the record with the same (bench,
    /// strategy, lookahead) key in `baseline_path`, failing when current
    /// throughput dropped more than `max_regression` (fractional, e.g.
    /// 0.2 = 20%). Keys present on only one side are skipped — the gate
    /// flags regressions, not coverage drift. Returns (comparisons,
    /// human summary); an empty baseline yields 0 comparisons and a
    /// "no baseline" note instead of an error, so CI can phase the gate
    /// in before the first toolchain run lands records.
    pub fn validate_serve_baseline(
        path: &Path,
        baseline_path: &Path,
        max_regression: f64,
    ) -> anyhow::Result<(usize, String)> {
        let current = read_serve_benches(path);
        let baseline = read_serve_benches(baseline_path);
        let base_hotpath: Vec<&ServeBenchRecord> = baseline
            .iter()
            .filter(|r| r.bench.contains("serve_hotpath"))
            .collect();
        if base_hotpath.is_empty() {
            return Ok((
                0,
                format!(
                    "{}: no serve_hotpath baseline records — regression gate \
                     skipped",
                    baseline_path.display()
                ),
            ));
        }
        let mut checked = 0usize;
        for b in &base_hotpath {
            let Some(c) = current.iter().find(|c| c.key() == b.key()) else {
                continue;
            };
            let floor = b.tokens_per_s * (1.0 - max_regression);
            anyhow::ensure!(
                c.tokens_per_s >= floor,
                "{} [{} lookahead={}]: {:.1} tok/s regressed below {:.1} \
                 (baseline {:.1}, max regression {:.0}%)",
                c.bench,
                c.strategy,
                c.lookahead,
                c.tokens_per_s,
                floor,
                b.tokens_per_s,
                max_regression * 100.0
            );
            checked += 1;
        }
        Ok((
            checked,
            format!("{checked} serve_hotpath record(s) within {:.0}% of baseline",
                max_regression * 100.0),
        ))
    }

    /// Merge-write: replaces on-disk records with the same (bench,
    /// strategy, lookahead) key and keeps the rest, so independent bench
    /// binaries accumulate into one trajectory file.
    pub fn record_serve_benches(
        path: &Path,
        records: &[ServeBenchRecord],
    ) -> std::io::Result<()> {
        let mut merged = read_serve_benches(path);
        merged.retain(|r| !records.iter().any(|n| n.key() == r.key()));
        merged.extend(records.iter().cloned());
        merged.sort_by_key(|r| r.key());
        let mut root = Value::obj();
        root.set("schema", Value::Str(SCHEMA.into())).set(
            "results",
            Value::Arr(merged.iter().map(ServeBenchRecord::to_json).collect()),
        );
        std::fs::write(path, root.to_string_pretty())
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        fn record(bench: &str, strategy: &str, lookahead: bool, tps: f64) -> ServeBenchRecord {
            ServeBenchRecord {
                bench: bench.into(),
                strategy: strategy.into(),
                lookahead,
                tokens_per_s: tps,
                hidden_transfer_ns: 123.0,
                exposed_transfer_ns: 456.0,
                hidden_bytes: 7,
                exposed_bytes: 8,
                ..Default::default()
            }
        }

        #[test]
        fn round_trips_and_merges_by_key() {
            let path = std::env::temp_dir().join(format!(
                "moe_gps_bench_emit_test_{}.json",
                std::process::id()
            ));
            let _ = std::fs::remove_file(&path);
            record_serve_benches(
                &path,
                &[record("a", "dop", false, 1.0), record("a", "dop", true, 2.0)],
            )
            .unwrap();
            // Same keys overwrite; new key accumulates.
            record_serve_benches(
                &path,
                &[record("a", "dop", true, 3.0), record("b", "tep", false, 4.0)],
            )
            .unwrap();
            let mut got = read_serve_benches(&path);
            got.sort_by_key(|r| r.key());
            assert_eq!(got.len(), 3);
            assert_eq!(got[0], record("a", "dop", false, 1.0));
            assert_eq!(got[1], record("a", "dop", true, 3.0));
            assert_eq!(got[2], record("b", "tep", false, 4.0));
            let text = std::fs::read_to_string(&path).unwrap();
            assert!(text.contains(SCHEMA));
            let _ = std::fs::remove_file(&path);
        }

        #[test]
        fn unreadable_file_reads_empty() {
            let path = std::env::temp_dir().join("moe_gps_bench_emit_missing.json");
            let _ = std::fs::remove_file(&path);
            assert!(read_serve_benches(&path).is_empty());
        }

        #[test]
        fn validate_accepts_written_files_and_rejects_garbage() {
            let path = std::env::temp_dir().join(format!(
                "moe_gps_bench_validate_test_{}.json",
                std::process::id()
            ));
            let _ = std::fs::remove_file(&path);
            assert!(validate_serve_benches(&path, false).is_err(), "missing file");

            record_serve_benches(&path, &[record("a", "dop", false, 1.5)]).unwrap();
            assert_eq!(validate_serve_benches(&path, true).unwrap(), 1);

            // Empty results: ok unless records are required.
            std::fs::write(
                &path,
                format!("{{\"schema\": \"{SCHEMA}\", \"results\": []}}"),
            )
            .unwrap();
            assert_eq!(validate_serve_benches(&path, false).unwrap(), 0);
            assert!(validate_serve_benches(&path, true).is_err());

            // Wrong schema tag.
            std::fs::write(&path, "{\"schema\": \"nope\", \"results\": []}").unwrap();
            assert!(validate_serve_benches(&path, false).is_err());

            // Malformed record.
            std::fs::write(
                &path,
                format!("{{\"schema\": \"{SCHEMA}\", \"results\": [{{\"bench\": 3}}]}}"),
            )
            .unwrap();
            assert!(validate_serve_benches(&path, false).is_err());
            let _ = std::fs::remove_file(&path);
        }

        fn kernel_record(bench: &str, tier: &str, eps: f64) -> ServeBenchRecord {
            ServeBenchRecord {
                bench: bench.into(),
                strategy: tier.into(),
                tokens_per_s: eps,
                gflops: Some(eps * 2.0 / 1e9),
                gbs: Some(eps * 8.0 / 1e9),
                ..Default::default()
            }
        }

        #[test]
        fn kernel_speedup_gate_compares_tiers() {
            let path = std::env::temp_dir().join(format!(
                "moe_gps_kernel_gate_test_{}.json",
                std::process::id()
            ));
            let _ = std::fs::remove_file(&path);
            assert!(validate_kernel_speedups(&path, 1.5).is_err(), "no records");

            // Forced-scalar: loud note, zero comparisons, no failure.
            record_serve_benches(&path, &[kernel_record("kernels/dot/4096", "scalar", 1e9)])
                .unwrap();
            let (n, msg) = validate_kernel_speedups(&path, 1.5).unwrap();
            assert_eq!(n, 0);
            assert!(msg.contains("forced-scalar"), "{msg}");

            // Vector tier at 2× passes a 1.5× bound, fails a 3× bound.
            record_serve_benches(
                &path,
                &[kernel_record("kernels/dot/4096", "avx2+fma", 2e9)],
            )
            .unwrap();
            let (n, _) = validate_kernel_speedups(&path, 1.5).unwrap();
            assert_eq!(n, 1);
            assert!(validate_kernel_speedups(&path, 3.0).is_err());

            // Non-dot kernels (axpy) are exempt from the bound.
            record_serve_benches(
                &path,
                &[
                    kernel_record("kernels/axpy/4096", "scalar", 1e9),
                    kernel_record("kernels/axpy/4096", "avx2+fma", 1.01e9),
                ],
            )
            .unwrap();
            assert!(validate_kernel_speedups(&path, 1.5).is_ok());
            let _ = std::fs::remove_file(&path);
        }

        #[test]
        fn baseline_gate_flags_hotpath_regression() {
            let dir = std::env::temp_dir();
            let cur = dir.join(format!("moe_gps_base_cur_{}.json", std::process::id()));
            let base = dir.join(format!("moe_gps_base_old_{}.json", std::process::id()));
            let _ = std::fs::remove_file(&cur);
            let _ = std::fs::remove_file(&base);

            // Missing baseline: gate skips with a note.
            record_serve_benches(&cur, &[record("serve_hotpath", "dop", false, 100.0)])
                .unwrap();
            let (n, msg) = validate_serve_baseline(&cur, &base, 0.2).unwrap();
            assert_eq!(n, 0);
            assert!(msg.contains("skipped"), "{msg}");

            // Within 20% of baseline: ok. Below: error.
            record_serve_benches(&base, &[record("serve_hotpath", "dop", false, 110.0)])
                .unwrap();
            let (n, _) = validate_serve_baseline(&cur, &base, 0.2).unwrap();
            assert_eq!(n, 1);
            record_serve_benches(&base, &[record("serve_hotpath", "dop", false, 200.0)])
                .unwrap();
            assert!(validate_serve_baseline(&cur, &base, 0.2).is_err());

            // Non-hotpath baseline records are ignored.
            let _ = std::fs::remove_file(&base);
            record_serve_benches(&base, &[record("decode_serve", "dop", false, 9e9)])
                .unwrap();
            let (n, _) = validate_serve_baseline(&cur, &base, 0.2).unwrap();
            assert_eq!(n, 0);
            let _ = std::fs::remove_file(&cur);
            let _ = std::fs::remove_file(&base);
        }

        #[test]
        fn forecast_gate_bounds_realized_l1() {
            let path = std::env::temp_dir().join(format!(
                "moe_gps_forecast_gate_test_{}.json",
                std::process::id()
            ));
            let _ = std::fs::remove_file(&path);
            assert!(validate_forecast_error(&path, 0.5).is_err(), "missing file");

            std::fs::write(&path, "{\"forecast_l1\": 0.12}").unwrap();
            let l1 = validate_forecast_error(&path, 0.5).unwrap();
            assert!((l1 - 0.12).abs() < 1e-15);
            assert!(validate_forecast_error(&path, 0.1).is_err(), "over bound");

            // Null / missing: no forecasts matured — the gate must fail
            // rather than silently pass a horizon-0 run.
            std::fs::write(&path, "{\"forecast_l1\": null}").unwrap();
            assert!(validate_forecast_error(&path, 0.5).is_err());
            std::fs::write(&path, "{\"tokens_per_s\": 9.0}").unwrap();
            assert!(validate_forecast_error(&path, 0.5).is_err());
            let _ = std::fs::remove_file(&path);
        }

        #[test]
        fn copy_gate_bounds_copied_fraction() {
            let path = std::env::temp_dir().join(format!(
                "moe_gps_copy_gate_test_{}.json",
                std::process::id()
            ));
            let _ = std::fs::remove_file(&path);
            assert!(validate_copied_frac(&path, 0.5).is_err(), "missing file");

            // copied/(copied+shared) = 0.25: inside 0.5, outside 0.1.
            std::fs::write(&path, "{\"bytes_copied\": 256, \"bytes_shared\": 768}")
                .unwrap();
            let frac = validate_copied_frac(&path, 0.5).unwrap();
            assert!((frac - 0.25).abs() < 1e-15);
            assert!(validate_copied_frac(&path, 0.1).is_err(), "over bound");

            // An idle plane (nothing moved) passes at fraction 0.
            std::fs::write(&path, "{\"bytes_copied\": 0, \"bytes_shared\": 0}")
                .unwrap();
            assert_eq!(validate_copied_frac(&path, 0.0).unwrap(), 0.0);

            // Pre-ADR-009 report (keys absent): the gate must fail rather
            // than silently pass a report that measured nothing.
            std::fs::write(&path, "{\"tokens_per_s\": 9.0}").unwrap();
            assert!(validate_copied_frac(&path, 0.5).is_err());
            std::fs::write(&path, "{\"bytes_copied\": 10}").unwrap();
            assert!(validate_copied_frac(&path, 0.5).is_err(), "half-missing");
            let _ = std::fs::remove_file(&path);
        }

        #[test]
        fn wavefront_gate_bounds_worker_idle_fraction() {
            let path = std::env::temp_dir().join(format!(
                "moe_gps_wavefront_gate_test_{}.json",
                std::process::id()
            ));
            let _ = std::fs::remove_file(&path);
            assert!(validate_wavefront_report(&path, 0.95).is_err(), "missing file");

            std::fs::write(
                &path,
                "{\"worker_idle_frac\": 0.42, \"leader_stall_s\": 0.003}",
            )
            .unwrap();
            let (idle, stall) = validate_wavefront_report(&path, 0.95).unwrap();
            assert!((idle - 0.42).abs() < 1e-15);
            assert!((stall - 0.003).abs() < 1e-15);
            assert!(validate_wavefront_report(&path, 0.4).is_err(), "over bound");

            // A fraction outside [0, 1] is a measurement bug, not a pass.
            std::fs::write(
                &path,
                "{\"worker_idle_frac\": 1.5, \"leader_stall_s\": 0.0}",
            )
            .unwrap();
            assert!(validate_wavefront_report(&path, 2.0).is_err());

            // Pre-ADR-010 report (keys absent): fail loudly rather than
            // silently pass a report that measured nothing.
            std::fs::write(&path, "{\"tokens_per_s\": 9.0}").unwrap();
            assert!(validate_wavefront_report(&path, 0.95).is_err());
            std::fs::write(&path, "{\"worker_idle_frac\": 0.1}").unwrap();
            assert!(validate_wavefront_report(&path, 0.95).is_err(), "half-missing");
            let _ = std::fs::remove_file(&path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_summary() {
        let b = Bencher::quick();
        let s = b.bench("noop_sum", || (0..100u64).sum::<u64>());
        assert!(s.iterations > 0);
        assert!(s.mean_s >= 0.0);
        assert!(s.min_s <= s.median_s);
        assert!(s.median_s <= s.max_s + 1e-12);
        assert!(s.p95_s >= s.median_s - 1e-12);
    }

    #[test]
    fn slower_closure_measures_slower() {
        let b = Bencher::quick();
        let fast = b.bench("fast", || black_box(1u64) + 1);
        let slow = b.bench("slow", || {
            let mut acc = 0u64;
            for i in 0..20_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert!(
            slow.median_s > fast.median_s * 5.0,
            "slow={} fast={}",
            slow.median_s,
            fast.median_s
        );
    }
}
