//! Bench: **decode-phase continuous batching** — steady-state tokens/sec
//! for none / Distribution-Only / Token-to-Expert on the real coordinator
//! (DESIGN.md §8; the decode acceptance target: DOP ≥ baseline).
//!
//! Runs against on-disk artifacts when present, otherwise the synthetic
//! tiny model (reference backend) — so this bench works in every build
//! environment. Also micro-benchmarks the scheduler hot paths and the
//! decode-regime analytical model.

use moe_gps::bench::emit::{bench_json_path, record_serve_benches, ServeBenchRecord};
use moe_gps::bench::{black_box, group, Bencher};
use moe_gps::coordinator::request::RequestGen;
use moe_gps::coordinator::{Coordinator, DecodeOptions, Scheduler, ServeStrategy};
use moe_gps::model::ModelConfig;
use moe_gps::sim::moe::Strategy;
use moe_gps::sim::{DecodeSim, SystemSpec};

fn main() {
    group("scheduler micro hot paths");
    let b = Bencher::default();
    b.run("admit_evict_64_requests", || {
        let mut sched = Scheduler::new(8);
        let mut gen = RequestGen::new(3, 4096);
        for _ in 0..64 {
            sched.push(gen.decode_request(16, 1));
        }
        let mut steps = 0usize;
        while !sched.is_idle() {
            for req in sched.admit(steps) {
                black_box(req.id);
            }
            let ids: Vec<u64> = sched.active().iter().map(|s| s.id).collect();
            for id in ids {
                sched.record_token(id);
            }
            sched.evict_finished();
            steps += 1;
        }
        steps
    });

    group("decode-regime analytical model (Mixtral 8x7B, 4xA100)");
    let sim = DecodeSim::new(
        ModelConfig::mixtral_8x7b(),
        SystemSpec::four_a100_nvlink(),
    );
    b.run("decode_step_breakdown", || {
        sim.step_breakdown(
            black_box(1.4),
            Strategy::DistributionOnly { error_rate: 0.018 },
        )
        .total()
    });
    for (name, strategy) in [
        ("none", Strategy::NoPrediction),
        ("dop", Strategy::DistributionOnly { error_rate: 0.018 }),
        (
            "tep",
            Strategy::TokenToExpert {
                accuracy: 0.9,
                overhead_s: 50e-6,
            },
        ),
    ] {
        println!(
            "    model: {name:<5} step={}  throughput={:>9.1} tok/s",
            moe_gps::util::human_time(sim.step_total(1.4, strategy)),
            sim.tokens_per_s(1.4, strategy),
        );
    }

    group("E2E continuous-batching decode (4 virtual GPUs, 8 seqs)");
    let artifacts = std::path::PathBuf::from("artifacts");
    let mut results: Vec<(&str, f64)> = Vec::new();
    let mut records: Vec<ServeBenchRecord> = Vec::new();
    for strategy in [
        ServeStrategy::NoPrediction,
        ServeStrategy::DistributionOnly,
        ServeStrategy::TokenToExpert,
    ] {
        let mut coord = Coordinator::new(&artifacts, 4, strategy).unwrap();
        coord.placement.replan_interval = 4;
        let mut gen = RequestGen::new(11, coord.vocab());
        // Warmup run: compile ops, upload weights, teach the estimators.
        let warm: Vec<_> = (0..4).map(|_| gen.decode_request(16, 8)).collect();
        coord.serve_decode(warm, &DecodeOptions::default()).unwrap();
        // Measured run: 8 sequences, all admitted up front → after the
        // prefill step every step is pure decode (steady state).
        let requests: Vec<_> = (0..8).map(|_| gen.decode_request(16, 24)).collect();
        let opts = DecodeOptions {
            max_active: 8,
            max_steps: 64,
            temperature: 1.0,
            seed: 17,
            arrival_interval: 0,
        };
        let report = coord.serve_decode(requests, &opts).unwrap();
        println!("  {}", report.summary());
        results.push((strategy.name(), report.steady_state_tokens_per_s()));
        records.push(ServeBenchRecord {
            bench: "decode_serve/e2e".into(),
            strategy: strategy.name().into(),
            lookahead: false,
            tokens_per_s: report.steady_state_tokens_per_s(),
            hidden_transfer_ns: report.total_hidden_transfer_s() * 1e9,
            exposed_transfer_ns: report.total_exposed_transfer_s() * 1e9,
            hidden_bytes: report.total_hidden_upload_bytes(),
            exposed_bytes: report.total_exposed_upload_bytes(),
            ..Default::default()
        });
    }
    let baseline = results
        .iter()
        .find(|(n, _)| *n == "none")
        .map(|&(_, t)| t)
        .unwrap_or(0.0);
    let dop = results
        .iter()
        .find(|(n, _)| *n == "distribution-only")
        .map(|&(_, t)| t)
        .unwrap_or(0.0);
    if baseline > 0.0 {
        let ratio = dop / baseline;
        println!(
            "\n  steady-state DOP vs baseline: {ratio:.3}x  [{}]",
            if ratio >= 1.0 { "PASS: DOP >= baseline" } else { "WARN: below baseline this run" }
        );
    }

    // Machine-readable trajectory (merged with pipeline_overlap's records).
    let path = bench_json_path();
    match record_serve_benches(&path, &records) {
        Ok(()) => println!("  wrote {} records to {}", records.len(), path.display()),
        Err(err) => println!("  WARN: could not write {}: {err}", path.display()),
    }
}
