//! Bench: **Figure 5** — prediction-error distribution scenarios
//! (optimistic / typical / pessimistic, paper §3.3) as an ablation: how the
//! same error rate ε maps to end-to-end latency under each scenario.

use moe_gps::bench::{black_box, group, Bencher};
use moe_gps::model::ModelConfig;
use moe_gps::sim::moe::Strategy;
use moe_gps::sim::{ErrorModel, LayerSim, SystemSpec};
use moe_gps::util::tablefmt::{f, Align, Table};

fn main() {
    let model = ModelConfig::mixtral_8x7b();
    let system = SystemSpec::four_a100_nvlink();

    group("Figure 5 — error-model scenarios (DOP, skew 1.4, NVLink)");
    let mut table = Table::new(&[
        "ε",
        "optimistic (ms)",
        "typical (ms)",
        "pessimistic (ms)",
        "baseline (ms)",
    ])
    .align(&[Align::Right; 5]);
    let skew = 1.4;
    for &eps in &[0.0, 0.05, 0.1, 0.2, 0.3, 0.5] {
        let mut cells = vec![f(eps, 2)];
        for em in [
            ErrorModel::Optimistic,
            ErrorModel::Typical,
            ErrorModel::Pessimistic,
        ] {
            let mut sim = LayerSim::new(model.clone(), system.clone());
            sim.error_model = em;
            let total = sim
                .breakdown(skew, Strategy::DistributionOnly { error_rate: eps })
                .total();
            cells.push(f(total * 1e3, 3));
        }
        let baseline = LayerSim::new(model.clone(), system.clone()).baseline_total(skew);
        cells.push(f(baseline * 1e3, 3));
        table.row(&cells);
    }
    println!("{}", table.render());
    println!(
        "shape check: optimistic ≤ typical ≤ pessimistic; pessimistic is an \
         upper bound that can exceed the baseline (paper §3.3)."
    );

    group("Figure 5 micro-benchmarks");
    let b = Bencher::default();
    let sim = LayerSim::new(model, system);
    b.run("layer_breakdown_eval", || {
        sim.breakdown(
            black_box(1.4),
            Strategy::DistributionOnly { error_rate: 0.1 },
        )
        .total()
    });
}
