//! Bench: **micro-batch wavefront pipelining** (ADR 010) — tokens/sec
//! and worker idle fraction vs the wavefront depth K on the same trace.
//! Serial serving (K = 1) leaves the fleet idle while the leader routes
//! and combines; the wavefront hides those stalls under in-flight FFN
//! slabs. Each leg serves identical rounds (the combine contract makes
//! them bitwise identical), so the tokens/sec column isolates the
//! overlap and the idle-fraction column shows where it came from.
//! Results append to `BENCH_serve.json` (schema `moe-gps/serve-bench/v1`)
//! and the CI bench-smoke wavefront gate bounds the idle fraction a
//! `--microbatch 4` serve report records.

use moe_gps::bench::emit::{bench_json_path, record_serve_benches, ServeBenchRecord};
use moe_gps::bench::{black_box, group, Bencher};
use moe_gps::coordinator::request::RequestGen;
use moe_gps::coordinator::{Coordinator, ServeReport, ServeStrategy};

/// The serving hot-path acceptance config (ISSUE 3): 8 virtual GPUs.
const E2E_WORKERS: usize = 8;

fn main() {
    let artifacts = std::path::PathBuf::from("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("no AOT artifacts — wavefront legs run the synthetic tiny model");
    }

    group(&format!(
        "wavefront depth sweep ({E2E_WORKERS} virtual GPUs, 4 seqs/round)"
    ));
    let quick = Bencher::quick();
    let mut records: Vec<ServeBenchRecord> = Vec::new();
    let mut serial_tps = 0.0f64;
    for k in [1usize, 2, 4, 8] {
        let mut coord =
            Coordinator::new(&artifacts, E2E_WORKERS, ServeStrategy::DistributionOnly).unwrap();
        coord.microbatch = k;
        let mut gen = RequestGen::new(13, coord.vocab());
        let max_len = coord.seq_len();
        // Warmup: compile + teach estimators + warm the tile pool.
        let warm: Vec<_> = (0..4).map(|_| gen.request_varlen(64, max_len)).collect();
        coord.serve_round(&warm).unwrap();
        let reqs: Vec<_> = (0..4).map(|_| gen.request_varlen(64, max_len)).collect();
        let summary = quick.bench(&format!("wavefront_round_k{k}"), || {
            coord.serve_round(black_box(&reqs)).unwrap().0.n_tokens
        });
        summary.print();
        // Occupancy from one measured round, aggregated the way a serve
        // report does (window-weighted idle, summed stall, peak tiles).
        let (m, _) = coord.serve_round(&reqs).unwrap();
        let stats = ServeReport {
            rounds: vec![m.clone()],
            ..Default::default()
        }
        .wavefront_stats();
        let tokens_per_s = if summary.median_s > 0.0 {
            m.n_tokens as f64 / summary.median_s
        } else {
            0.0
        };
        if k == 1 {
            serial_tps = tokens_per_s;
        }
        println!(
            "    K={k}: {:.1} tok/s{} | idle frac {:.3} | leader stall {} | \
             tile peak {} | {} RunBatch msgs ({} slots)",
            tokens_per_s,
            if k > 1 && serial_tps > 0.0 {
                format!(" ({:+.1}% vs serial)", (tokens_per_s / serial_tps - 1.0) * 100.0)
            } else {
                String::new()
            },
            stats.worker_idle_frac,
            moe_gps::util::human_time(stats.leader_stall_s),
            stats.tile_peak,
            m.ffn_messages,
            m.n_slots,
        );
        records.push(ServeBenchRecord {
            bench: format!("wavefront/k{k}"),
            strategy: "dop".into(),
            lookahead: false,
            tokens_per_s,
            ..Default::default()
        });
    }

    let path = bench_json_path();
    match record_serve_benches(&path, &records) {
        Ok(()) => println!("\nwrote {} records to {}", records.len(), path.display()),
        Err(err) => println!("\nWARN: could not write {}: {err}", path.display()),
    }
}
