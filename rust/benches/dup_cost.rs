//! Bench: **§5 movement-cost arithmetic** (expert duplication's
//! communication overhead) + Algorithm-1 micro-benchmarks.
//!
//! Paper: a Mixtral 8×7B fp16 expert ≈ 4096·14336·2·2 bytes; one expert
//! per GPU per layer over NVLink 3.0 (2 TB/s) ≈ 0.1 ms, hidden under
//! attention at bs 1 / seq 512; PCIe 4.0 needs a larger workload.

use moe_gps::bench::{black_box, group, Bencher};
use moe_gps::duplication::algorithm::{balance, balance_fractional};
use moe_gps::duplication::cost::{min_hiding_batch, movement_report};
use moe_gps::duplication::dispatch::dispatch_tokens;
use moe_gps::duplication::Placement;
use moe_gps::model::ModelConfig;
use moe_gps::sim::SystemSpec;
use moe_gps::util::rng::Rng;
use moe_gps::util::tablefmt::{f, Align, Table};

fn main() {
    let model = ModelConfig::mixtral_8x7b();

    group("§5 — expert-movement cost vs attention hiding window");
    let mut t = Table::new(&[
        "interconnect",
        "batch",
        "seq",
        "transfer (ms)",
        "attention (ms)",
        "exposed (ms)",
        "hidden",
    ])
    .align(&[
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Left,
    ]);
    for sys in [SystemSpec::four_a100_nvlink(), SystemSpec::four_a100_pcie()] {
        for (b, s) in [(1usize, 512usize), (4, 512), (16, 2048), (64, 2048)] {
            let r = movement_report(&model, &sys, b, s, 1);
            t.row(&[
                sys.interconnect.name.clone(),
                b.to_string(),
                s.to_string(),
                f(r.transfer_s * 1e3, 3),
                f(r.attention_compute_s * 1e3, 3),
                f(r.exposed_s * 1e3, 3),
                r.hidden.to_string(),
            ]);
        }
    }
    println!("{}", t.render());
    let pcie = SystemSpec::four_a100_pcie();
    println!(
        "min batch hiding PCIe movement at seq 2048: {:?} (paper: 'modest' — their \
         conservative attention estimate hides at 16)",
        min_hiding_batch(&model, &pcie, 2048, 1, 128)
    );

    group("Algorithm 1 micro-benchmarks");
    let b = Bencher::default();
    let mut rng = Rng::new(5);
    let counts_small: Vec<usize> = (0..8).map(|_| rng.range(0, 400)).collect();
    let counts_large: Vec<usize> = (0..64).map(|_| rng.range(0, 4000)).collect();
    let init8 = Placement::initial(8, 4, 8, 4);
    let init64 = Placement::initial(64, 16, 8, 16);
    b.run("balance_8experts_4gpus", || {
        balance(black_box(&counts_small), &init8).max_load()
    });
    b.run("balance_64experts_16gpus", || {
        balance(black_box(&counts_large), &init64).max_load()
    });
    let probs: Vec<f64> = moe_gps::util::stats::normalize(
        &counts_small.iter().map(|&c| c as f64).collect::<Vec<_>>(),
    );
    b.run("balance_fractional_dop", || {
        balance_fractional(black_box(&probs), &init8).1.len()
    });
    let experts: Vec<u8> = (0..2048).map(|_| rng.range(0, 8) as u8).collect();
    let balanced = balance(&counts_small, &init8);
    b.run("dispatch_2048_slots", || {
        dispatch_tokens(black_box(&experts), &balanced.placement).1
    });
}
