//! Bench: **E2E serving hot path** — the real coordinator over PJRT
//! (requires `make artifacts`; prints a skip message otherwise). This is
//! the §Perf measurement target: round latency per strategy, plus the
//! coordinator-side micro hot paths (top-k routing, gather/pad, combine).

use moe_gps::bench::{black_box, group, Bencher};
use moe_gps::coordinator::request::RequestGen;
use moe_gps::coordinator::router::route_sequence;
use moe_gps::coordinator::{Coordinator, ServeStrategy};
use moe_gps::runtime::HostTensor;
use moe_gps::util::rng::Rng;

fn main() {
    group("coordinator micro hot paths (no PJRT)");
    let b = Bencher::default();
    let mut rng = Rng::new(3);
    let logits: Vec<f32> = (0..256 * 8).map(|_| rng.normal() as f32).collect();
    b.run("top2_route_256_tokens", || {
        route_sequence(0, black_box(&logits), 8, 256, 2).len()
    });
    let tensor = HostTensor::new(
        (0..256 * 256).map(|i| i as f32).collect(),
        vec![256, 256],
    );
    let rows: Vec<usize> = (0..200).map(|i| (i * 7) % 256).collect();
    b.run("gather_200_rows_d256", || {
        tensor.gather_rows(black_box(&rows)).rows()
    });
    b.run("pad_200_to_256", || {
        tensor
            .gather_rows(&rows)
            .pad_rows_to(black_box(256))
            .rows()
    });

    let artifacts = std::path::PathBuf::from("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("\nno AOT artifacts — E2E rounds run the synthetic tiny model");
    }

    group("E2E serving rounds (4 virtual GPUs, 2 seqs/round)");
    let quick = Bencher::quick();
    for strategy in [
        ServeStrategy::NoPrediction,
        ServeStrategy::DistributionOnly,
        ServeStrategy::TokenToExpert,
    ] {
        let mut coord = Coordinator::new(&artifacts, 4, strategy).unwrap();
        let mut gen = RequestGen::new(11, coord.vocab());
        let max_len = coord.seq_len();
        // Warmup: compile + teach estimators.
        let warm: Vec<_> = (0..2).map(|_| gen.request_varlen(64, max_len)).collect();
        coord.serve_round(&warm).unwrap();
        let reqs: Vec<_> = (0..2).map(|_| gen.request_varlen(64, max_len)).collect();
        let summary = quick.bench(&format!("serve_round_{}", strategy.name()), || {
            coord.serve_round(black_box(&reqs)).unwrap().0.n_tokens
        });
        summary.print();
        // Strategy-specific stats from one measured round.
        let (m, _) = coord.serve_round(&reqs).unwrap();
        println!(
            "    breakdown: embed {} | predict+plan {} | attention {} | router {} | ffn {} \
             | slot imbalance {:.3}",
            moe_gps::util::human_time(m.embed_s),
            moe_gps::util::human_time(m.predictor_s),
            moe_gps::util::human_time(m.attention_s),
            moe_gps::util::human_time(m.router_s),
            moe_gps::util::human_time(m.ffn_wall_s),
            m.slot_imbalance(),
        );
    }
}
