//! Bench: **E2E serving hot path** — the real coordinator (artifacts
//! when present, synthetic tiny model otherwise). This is the §Perf
//! measurement target: round latency and tokens/sec per strategy on the
//! default 8-worker config, plus the coordinator-side micro hot paths
//! (top-k routing, gather/pad, combine). Results are appended to
//! `BENCH_serve.json` (schema `moe-gps/serve-bench/v1`) so the perf
//! trajectory is tracked across PRs — the CI bench-smoke job runs this
//! bench and validates the emitted file.

use moe_gps::bench::emit::{bench_json_path, record_serve_benches, ServeBenchRecord};
use moe_gps::bench::{black_box, group, Bencher};
use moe_gps::coordinator::request::RequestGen;
use moe_gps::coordinator::router::route_sequence;
use moe_gps::coordinator::{Coordinator, ServeStrategy};
use moe_gps::runtime::HostTensor;
use moe_gps::util::rng::Rng;

/// The acceptance config for the serving hot path (ISSUE 3): 8 virtual
/// GPUs, 2 sequences per round.
const E2E_WORKERS: usize = 8;

fn main() {
    group("coordinator micro hot paths (no PJRT)");
    let b = Bencher::default();
    let mut rng = Rng::new(3);
    let logits: Vec<f32> = (0..256 * 8).map(|_| rng.normal() as f32).collect();
    b.run("top2_route_256_tokens", || {
        route_sequence(0, black_box(&logits), 8, 256, 2).len()
    });
    let tensor = HostTensor::new(
        (0..256 * 256).map(|i| i as f32).collect(),
        vec![256, 256],
    );
    let rows: Vec<usize> = (0..200).map(|i| (i * 7) % 256).collect();
    b.run("gather_200_rows_d256", || {
        tensor.gather_rows(black_box(&rows)).rows()
    });
    b.run("pad_200_to_256", || {
        tensor
            .gather_rows(&rows)
            .pad_rows_to(black_box(256))
            .rows()
    });

    let artifacts = std::path::PathBuf::from("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("\nno AOT artifacts — E2E rounds run the synthetic tiny model");
    }

    group(&format!(
        "E2E serving rounds ({E2E_WORKERS} virtual GPUs, 2 seqs/round)"
    ));
    let quick = Bencher::quick();
    let mut records: Vec<ServeBenchRecord> = Vec::new();
    for strategy in [
        ServeStrategy::NoPrediction,
        ServeStrategy::DistributionOnly,
        ServeStrategy::TokenToExpert,
    ] {
        let mut coord = Coordinator::new(&artifacts, E2E_WORKERS, strategy).unwrap();
        let mut gen = RequestGen::new(11, coord.vocab());
        let max_len = coord.seq_len();
        // Warmup: compile + teach estimators + warm the tile pool.
        let warm: Vec<_> = (0..2).map(|_| gen.request_varlen(64, max_len)).collect();
        coord.serve_round(&warm).unwrap();
        let reqs: Vec<_> = (0..2).map(|_| gen.request_varlen(64, max_len)).collect();
        let n_tokens: usize = reqs.iter().map(|r| r.tokens.len().min(max_len)).sum();
        let summary = quick.bench(&format!("serve_round_{}", strategy.name()), || {
            coord.serve_round(black_box(&reqs)).unwrap().0.n_tokens
        });
        summary.print();
        let tokens_per_s = if summary.median_s > 0.0 {
            n_tokens as f64 / summary.median_s
        } else {
            0.0
        };
        println!("    end-to-end: {tokens_per_s:.1} tok/s ({n_tokens} tokens/round)");
        // Strategy-specific stats from one measured round.
        let (m, _) = coord.serve_round(&reqs).unwrap();
        println!(
            "    breakdown: embed {} | predict+plan {} | attention {} | router {} | ffn {} \
             | slot imbalance {:.3} | tile reuse {}/{}",
            moe_gps::util::human_time(m.embed_s),
            moe_gps::util::human_time(m.predictor_s),
            moe_gps::util::human_time(m.attention_s),
            moe_gps::util::human_time(m.router_s),
            moe_gps::util::human_time(m.ffn_wall_s),
            m.slot_imbalance(),
            m.tile_reuses,
            m.tile_allocs + m.tile_reuses,
        );
        records.push(ServeBenchRecord {
            bench: "serve_hotpath/round".into(),
            strategy: strategy.name().into(),
            lookahead: false,
            tokens_per_s,
            hidden_transfer_ns: m.hidden_transfer_s * 1e9,
            exposed_transfer_ns: m.exposed_transfer_s * 1e9,
            hidden_bytes: m.hidden_upload_bytes,
            exposed_bytes: m.exposed_upload_bytes,
            ..Default::default()
        });
    }

    let path = bench_json_path();
    match record_serve_benches(&path, &records) {
        Ok(()) => println!("\nwrote {} records to {}", records.len(), path.display()),
        Err(err) => println!("\nWARN: could not write {}: {err}", path.display()),
    }
}
