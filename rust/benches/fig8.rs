//! Bench: **Figure 8** — the Figure-6 experiment on LLaMA-MoE [37]
//! (paper Appendix C). Same workload and hardware; the traces run higher
//! skew and near-perfect prediction gets exponentially expensive, so
//! high-overhead TEP points are omitted (the paper omits overhead > 0.5×).

use moe_gps::bench::group;
use moe_gps::gps::calibrate::calibrate_all;
use moe_gps::gps::sweep::{figure6_skews, skew_sweep};
use moe_gps::gps::{report, strategy_savings};
use moe_gps::model::ModelConfig;
use moe_gps::sim::SystemSpec;

fn main() {
    let fast = std::env::var("MOE_GPS_FAST").is_ok();
    let model = ModelConfig::llama_moe();

    for (title, system) in [
        ("Figure 8a/8b — LLaMA-MoE, NVLink", SystemSpec::four_a100_nvlink()),
        ("Figure 8c/8d — LLaMA-MoE, PCIe", SystemSpec::four_a100_pcie()),
    ] {
        group(title);
        let cals = calibrate_all(&model, &system, fast, 21);
        let points = skew_sweep(&model, &system, &cals, &figure6_skews(), 1, 512);
        // Omit points whose overhead exceeds 0.5× the baseline, as the
        // paper does for illustration.
        let kept: Vec<_> = points
            .into_iter()
            .filter(|p| {
                p.breakdown.overhead_s
                    <= 0.5 * p.total_s.max(p.breakdown.overhead_s + 1e-12)
            })
            .collect();
        println!("{}", report::figure6(&kept, title));
        let cmp = strategy_savings(&model, &system, &cals, 2.0, 1, 512);
        println!(
            "skew 2.0 on {}: DOP saving {:.3} ms vs best-TEP saving {:.3} ms",
            system.interconnect.name,
            cmp.dop_saving_s * 1e3,
            cmp.tep_best_saving_s * 1e3,
        );
    }
}
