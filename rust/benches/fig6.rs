//! Bench: **Figure 6** — single-layer Mixtral 8×7B prefill latency under
//! each prediction strategy, across skewness levels, on NVLink (a, b) and
//! PCIe (c, d) (paper §4). The headline: at skew 1.4 on NVLink,
//! Distribution-Only beats the best Token-to-Expert configuration by >23%.

use moe_gps::bench::{black_box, group, Bencher};
use moe_gps::gps::calibrate::calibrate_all;
use moe_gps::gps::sweep::{figure6_skews, skew_sweep};
use moe_gps::gps::{report, strategy_savings};
use moe_gps::model::ModelConfig;
use moe_gps::sim::SystemSpec;

fn main() {
    let fast = std::env::var("MOE_GPS_FAST").is_ok();
    let model = ModelConfig::mixtral_8x7b();

    for (title, system) in [
        ("Figure 6a/6b — NVLink", SystemSpec::four_a100_nvlink()),
        ("Figure 6c/6d — PCIe", SystemSpec::four_a100_pcie()),
    ] {
        group(title);
        let cals = calibrate_all(&model, &system, fast, 7);
        let points = skew_sweep(&model, &system, &cals, &figure6_skews(), 1, 512);
        println!("{}", report::figure6(&points, title));

        // Headline check at skew 1.4.
        let cmp = strategy_savings(&model, &system, &cals, 1.4, 1, 512);
        let dop_total = cmp.baseline_s - cmp.dop_saving_s;
        let tep_total = cmp.baseline_s - cmp.tep_best_saving_s;
        println!(
            "skew 1.4 on {}: DOP total {:.3} ms vs best-TEP total {:.3} ms \
             → DOP advantage {:.1}% (paper claims >23% on NVLink/MMLU)",
            system.interconnect.name,
            dop_total * 1e3,
            tep_total * 1e3,
            (tep_total / dop_total - 1.0) * 100.0,
        );
    }

    group("Figure 6 micro-benchmarks");
    let b = Bencher::default();
    let system = SystemSpec::four_a100_nvlink();
    let cals = calibrate_all(&model, &system, true, 13);
    b.run("full_skew_sweep", || {
        skew_sweep(
            black_box(&model),
            &system,
            &cals,
            &figure6_skews(),
            1,
            512,
        )
        .len()
    });
}
