//! Bench: **Figure 1** — the MoE-GPS guideline chart: which prediction
//! strategy minimises end-to-end latency per (skewness × interconnect)
//! region. This is the framework's *output*; the chart is derived from the
//! same sweeps as Figures 6/7.

use moe_gps::bench::group;
use moe_gps::gps::calibrate::calibrate_all;
use moe_gps::gps::guidelines;
use moe_gps::model::ModelConfig;
use moe_gps::sim::SystemSpec;

fn main() {
    let fast = std::env::var("MOE_GPS_FAST").is_ok();
    let model = ModelConfig::mixtral_8x7b();

    group("Figure 1 — guideline decision map");
    let reference = SystemSpec::four_a100_nvlink();
    let cals = calibrate_all(&model, &reference, fast, 7);
    let skews = [1.0, 1.4, 2.0, 3.0, 4.0];
    let bandwidths = [600.0, 300.0, 128.0, 64.0, 32.0];
    let cells = guidelines::decision_map(&model, &cals, &skews, &bandwidths, 1, 512);
    println!("{}", guidelines::render_map(&cells, &skews, &bandwidths));
    println!("{}", guidelines::summarize(&cells));
    println!(
        "\npaper Figure 1 shape: Distribution-Only in the fast-interconnect /\n\
         low-skew region; Token-to-Expert toward slow interconnects and high skew."
    );
}
