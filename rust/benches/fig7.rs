//! Bench: **Figure 7** — difference between Distribution-Only's saving and
//! the best Token-to-Expert saving, per interconnect bandwidth
//! (600/300/128/64 GB/s) × skewness (paper §4). Bars above zero mean
//! Distribution-Only wins; TEP catches up as bandwidth drops / skew rises.

use moe_gps::bench::group;
use moe_gps::gps::calibrate::calibrate_all;
use moe_gps::gps::{report, strategy_savings};
use moe_gps::model::ModelConfig;
use moe_gps::sim::SystemSpec;

fn main() {
    let fast = std::env::var("MOE_GPS_FAST").is_ok();
    let model = ModelConfig::mixtral_8x7b();

    group("Figure 7 — DOP saving − best-TEP saving across interconnects");
    let mut rows = Vec::new();
    for bw in [600.0, 300.0, 128.0, 64.0] {
        let system = SystemSpec::four_a100_custom_bw(bw);
        let cals = calibrate_all(&model, &system, fast, 7);
        for skew in [1.4, 2.0, 3.0, 4.0] {
            rows.push(strategy_savings(&model, &system, &cals, skew, 1, 512));
        }
    }
    println!("{}", report::figure7(&rows));

    // Shape check: the minimum (most TEP-favourable) difference should be
    // at the lowest bandwidth + highest skew corner.
    let rel = |r: &moe_gps::gps::SavingsComparison| r.difference_s / r.baseline_s;
    let at = |bw: f64, sk: f64| {
        rows.iter()
            .find(|r| r.interconnect_gbs == bw && r.skewness == sk)
            .map(rel)
            .unwrap()
    };
    println!(
        "relative difference: (600 GB/s, skew 1.4) = {:+.3}  →  (64 GB/s, skew 4.0) = {:+.3}",
        at(600.0, 1.4),
        at(64.0, 4.0)
    );
    println!(
        "shape check: TEP gains (difference shrinks) toward low bandwidth / high skew: {}",
        if at(64.0, 4.0) < at(600.0, 1.4) { "OK" } else { "MISMATCH" }
    );
}
