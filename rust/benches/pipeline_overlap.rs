//! Bench: **lookahead overlap** (ADR 002) — the unified layer pipeline
//! with async expert pre-warming, off vs on, on the real coordinator.
//!
//! Reports steady-state decode tokens/sec for Distribution-Only in both
//! regimes (acceptance: lookahead ≥ no-lookahead), the hidden-vs-exposed
//! duplication-transfer split from a cold start, and the analytical
//! overlap cost model alongside. Results are appended to
//! `BENCH_serve.json` (merged by bench/strategy/lookahead) so the perf
//! trajectory is tracked across PRs.
//!
//! Runs against on-disk artifacts when present, otherwise the synthetic
//! tiny model (reference backend) — so it works in every build
//! environment.

use moe_gps::bench::emit::{bench_json_path, record_serve_benches, ServeBenchRecord};
use moe_gps::bench::group;
use moe_gps::coordinator::request::RequestGen;
use moe_gps::coordinator::{Coordinator, DecodeOptions, ServeStrategy};
use moe_gps::model::ModelConfig;
use moe_gps::sim::moe::Strategy;
use moe_gps::sim::{DecodeSim, LayerSim, SystemSpec};

fn main() {
    let artifacts = std::path::PathBuf::from("artifacts");
    let mut records: Vec<ServeBenchRecord> = Vec::new();

    group("E2E decode: DOP with lookahead off vs on (4 vGPUs, 8 seqs)");
    let mut steady = [0.0f64; 2];
    for (idx, lookahead) in [0usize, 1].into_iter().enumerate() {
        let mut coord =
            Coordinator::new(&artifacts, 4, ServeStrategy::DistributionOnly).unwrap();
        coord.lookahead = lookahead;
        coord.placement.replan_interval = 4;
        let mut gen = RequestGen::new(11, coord.vocab());
        // Cold run: weights stream in here, so this is where the
        // hidden-vs-exposed transfer split is visible.
        let cold_requests: Vec<_> = (0..4).map(|_| gen.decode_request(16, 8)).collect();
        let cold = coord
            .serve_decode(cold_requests, &DecodeOptions::default())
            .unwrap();
        // Measured run: weights resident → pure steady-state throughput.
        let requests: Vec<_> = (0..8).map(|_| gen.decode_request(16, 24)).collect();
        let opts = DecodeOptions {
            max_active: 8,
            max_steps: 64,
            temperature: 1.0,
            seed: 17,
            arrival_interval: 0,
        };
        let report = coord.serve_decode(requests, &opts).unwrap();
        println!("  lookahead={lookahead}: {}", report.summary());
        println!(
            "    cold-start transfer: hidden {} B / exposed {} B  \
             (hidden {:.1} us worker time, exposed {:.1} us leader stall)",
            cold.total_hidden_upload_bytes(),
            cold.total_exposed_upload_bytes(),
            cold.total_hidden_transfer_s() * 1e6,
            cold.total_exposed_transfer_s() * 1e6,
        );
        steady[idx] = report.steady_state_tokens_per_s();
        records.push(ServeBenchRecord {
            bench: "pipeline_overlap/decode_dop".into(),
            strategy: "distribution-only".into(),
            lookahead: lookahead > 0,
            tokens_per_s: report.steady_state_tokens_per_s(),
            hidden_transfer_ns: cold.total_hidden_transfer_s() * 1e9,
            exposed_transfer_ns: cold.total_exposed_transfer_s() * 1e9,
            hidden_bytes: cold.total_hidden_upload_bytes(),
            exposed_bytes: cold.total_exposed_upload_bytes(),
            ..Default::default()
        });
    }
    let ratio = if steady[0] > 0.0 { steady[1] / steady[0] } else { 0.0 };
    println!(
        "\n  steady-state DOP lookahead vs baseline: {ratio:.3}x  [{}]",
        if ratio >= 1.0 {
            "PASS: lookahead >= no-lookahead"
        } else {
            "WARN: below no-lookahead this run"
        }
    );

    group("E2E prefill: DOP round with lookahead (hidden-transfer check)");
    {
        let mut coord =
            Coordinator::new(&artifacts, 4, ServeStrategy::DistributionOnly).unwrap();
        coord.lookahead = 1;
        let mut gen = RequestGen::new(7, coord.vocab());
        let max_len = coord.seq_len();
        // Two rounds teach the estimators the synthetic trace's skew; the
        // third round duplicates hot experts and prewarms the replicas.
        let mut last_hidden = 0u64;
        for round in 0..3 {
            let requests: Vec<_> =
                (0..4).map(|_| gen.request_varlen(max_len / 4, max_len)).collect();
            let (m, _) = coord.serve_round(&requests).unwrap();
            println!(
                "  round {round}: replicas_added={} transfer hidden {} B / exposed {} B",
                m.replicas_added, m.hidden_upload_bytes, m.exposed_upload_bytes
            );
            last_hidden = m.hidden_upload_bytes.max(last_hidden);
        }
        println!(
            "  hidden duplication transfer observed: {} [{}]",
            last_hidden,
            if last_hidden > 0 { "PASS: > 0 bytes hidden" } else { "WARN: nothing hidden" }
        );
    }

    group("analytical overlap cost model (Mixtral 8x7B, 4xA100)");
    let model = ModelConfig::mixtral_8x7b();
    let system = SystemSpec::four_a100_nvlink();
    let tep = Strategy::TokenToExpert {
        accuracy: 0.9,
        overhead_s: 100e-6,
    };
    for (name, sim_total, overlapped_total) in [
        (
            "prefill tep",
            LayerSim::new(model.clone(), system.clone()).breakdown(1.4, tep).total(),
            LayerSim::new(model.clone(), system.clone())
                .with_overlap(true)
                .breakdown(1.4, tep)
                .total(),
        ),
        (
            "decode  tep",
            DecodeSim::new(model.clone(), system.clone()).step_total(1.4, tep),
            DecodeSim::new(model, system).with_overlap(true).step_total(1.4, tep),
        ),
    ] {
        println!(
            "    model: {name}  plain={}  overlap={}",
            moe_gps::util::human_time(sim_total),
            moe_gps::util::human_time(overlapped_total),
        );
    }

    let path = bench_json_path();
    match record_serve_benches(&path, &records) {
        Ok(()) => println!("\nwrote {} records to {}", records.len(), path.display()),
        Err(err) => println!("\nWARN: could not write {}: {err}", path.display()),
    }
}
