//! Bench: **SIMD lane kernels** (ADR 007) — per-kernel GB/s and GFLOP/s
//! for the dot / AXPY / max-reduce primitives that dominate the serve hot
//! path, scalar vs the dispatched vector tier, plus a matmul built from
//! the same primitives. Records land in `BENCH_serve.json` (schema
//! `moe-gps/serve-bench/v1`) with `bench = "kernels/<op>/<shape>"` and
//! `strategy` = the dispatch tier, so `bench-validate
//! --min-kernel-speedup` can gate the scalar-vs-simd ratio. When no
//! vector ISA is available (or `MOE_GPS_SIMD=scalar` forces the portable
//! path) only scalar records are written and that is announced loudly —
//! the validator reports it rather than silently passing.

use moe_gps::bench::emit::{bench_json_path, record_serve_benches, ServeBenchRecord};
use moe_gps::bench::{black_box, group, Bencher};
use moe_gps::runtime::simd;
use moe_gps::util::rng::Rng;

/// One measured rate: elements/sec plus derived arithmetic and traffic
/// rates for the record.
fn record(bench: String, tier: &str, elems_per_s: f64, flops_per_elem: f64, bytes_per_elem: f64) -> ServeBenchRecord {
    ServeBenchRecord {
        bench,
        strategy: tier.into(),
        tokens_per_s: elems_per_s,
        gflops: Some(elems_per_s * flops_per_elem / 1e9),
        gbs: Some(elems_per_s * bytes_per_elem / 1e9),
        ..Default::default()
    }
}

fn rate(b: &Bencher, name: &str, n: usize, mut f: impl FnMut() -> f32) -> f64 {
    let s = b.bench(name, &mut f);
    s.print();
    if s.median_s > 0.0 {
        n as f64 / s.median_s
    } else {
        0.0
    }
}

/// The reference backend's per-row matmul structure (blocked ikj over
/// AXPY), parameterised on the AXPY used — so scalar and dispatched
/// tiers run the identical loop nest and only the lane kernel differs.
fn matmul_via(
    axpy: fn(f32, &[f32], &mut [f32]),
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    out: &mut [f32],
) {
    const K_TILE: usize = 64;
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for k0 in (0..k).step_by(K_TILE) {
            let k1 = (k0 + K_TILE).min(k);
            for (kk, &av) in arow[k0..k1].iter().enumerate() {
                axpy(av, &b[(k0 + kk) * n..(k0 + kk + 1) * n], orow);
            }
        }
    }
}

fn main() {
    let tier = simd::active_tier();
    let vector = tier != simd::Tier::Scalar;
    println!(
        "SIMD dispatch tier: {} ({} lanes canonical accumulation)",
        tier.name(),
        simd::LANES
    );
    if !vector {
        println!(
            "NOTE: forced-scalar dispatch — no vector ISA (or MOE_GPS_SIMD=scalar); \
             only scalar records will be written"
        );
    }

    let b = Bencher::default();
    let mut rng = Rng::new(7);
    let mut records: Vec<ServeBenchRecord> = Vec::new();

    // Sanity: dispatched and portable must agree bitwise before we time
    // anything (the determinism contract the test suite pins down).
    {
        let x: Vec<f32> = (0..4099).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..4099).map(|_| rng.normal() as f32).collect();
        assert_eq!(
            simd::dot(&x, &y).to_bits(),
            simd::dot_portable(&x, &y).to_bits(),
            "dispatched dot diverged from the portable kernel"
        );
        assert_eq!(
            simd::max_reduce(&x).to_bits(),
            simd::max_reduce_portable(&x).to_bits(),
            "dispatched max_reduce diverged from the portable kernel"
        );
    }

    group("dot product (q·k attention scores, lm_head logits)");
    for n in [1024usize, 4096, 65536] {
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let scalar =
            rate(&b, &format!("dot/{n}/scalar"), n, || simd::dot_portable(black_box(&x), black_box(&y)));
        records.push(record(format!("kernels/dot/{n}"), "scalar", scalar, 2.0, 8.0));
        if vector {
            let fast = rate(&b, &format!("dot/{n}/{}", tier.name()), n, || {
                simd::dot(black_box(&x), black_box(&y))
            });
            records.push(record(format!("kernels/dot/{n}"), tier.name(), fast, 2.0, 8.0));
            println!("    speedup: {:.2}x", fast / scalar.max(1.0));
        }
    }

    group("AXPY (matmul inner loop, attention V-accumulate)");
    for n in [1024usize, 4096, 65536] {
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut y: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let scalar = rate(&b, &format!("axpy/{n}/scalar"), n, || {
            simd::axpy_portable(1.0001, black_box(&x), black_box(&mut y));
            y[0]
        });
        records.push(record(format!("kernels/axpy/{n}"), "scalar", scalar, 2.0, 12.0));
        if vector {
            let fast = rate(&b, &format!("axpy/{n}/{}", tier.name()), n, || {
                simd::axpy(1.0001, black_box(&x), black_box(&mut y));
                y[0]
            });
            records.push(record(format!("kernels/axpy/{n}"), tier.name(), fast, 2.0, 12.0));
            println!("    speedup: {:.2}x", fast / scalar.max(1.0));
        }
    }

    group("max-reduce (softmax row max)");
    for n in [1024usize, 4096, 65536] {
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let scalar = rate(&b, &format!("max_reduce/{n}/scalar"), n, || {
            simd::max_reduce_portable(black_box(&x))
        });
        records.push(record(format!("kernels/max_reduce/{n}"), "scalar", scalar, 1.0, 4.0));
        if vector {
            let fast = rate(&b, &format!("max_reduce/{n}/{}", tier.name()), n, || {
                simd::max_reduce(black_box(&x))
            });
            records.push(record(format!("kernels/max_reduce/{n}"), tier.name(), fast, 1.0, 4.0));
            println!("    speedup: {:.2}x", fast / scalar.max(1.0));
        }
    }

    group("matmul on the lane kernels (blocked ikj, single thread)");
    for (m, k, n) in [(64usize, 512usize, 256usize), (1, 512, 512)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let bm: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0f32; m * n];
        let flops = (2 * m * k * n) as f64;
        let bytes = (4 * (m * k + k * n + m * n)) as f64;
        let shape = format!("{m}x{k}x{n}");
        let scalar = rate(&b, &format!("matmul/{shape}/scalar"), 1, || {
            matmul_via(simd::axpy_portable, &a, m, k, &bm, n, black_box(&mut out));
            out[0]
        });
        records.push(record(format!("kernels/matmul/{shape}"), "scalar", scalar, flops, bytes));
        if vector {
            let fast = rate(&b, &format!("matmul/{shape}/{}", tier.name()), 1, || {
                matmul_via(simd::axpy, &a, m, k, &bm, n, black_box(&mut out));
                out[0]
            });
            records.push(record(format!("kernels/matmul/{shape}"), tier.name(), fast, flops, bytes));
            println!("    speedup: {:.2}x", fast / scalar.max(f64::MIN_POSITIVE));
        }
    }

    let path = bench_json_path();
    match record_serve_benches(&path, &records) {
        Ok(()) => println!("\nwrote {} records to {}", records.len(), path.display()),
        Err(err) => println!("\nWARN: could not write {}: {err}", path.display()),
    }
}
