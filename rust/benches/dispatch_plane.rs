//! Bench: **zero-copy data plane** (ADR 009) — what the dispatch/combine
//! path actually moves. Micro legs price the two mechanisms against the
//! per-group plane they replaced (`Arc` share vs deep clone for the
//! attention fan-out, pooled `gather_rows_into` vs fresh-alloc gather for
//! the slab build); the E2E legs serve real rounds and report copied
//! GB/s and dispatch messages per token from the ADR 009 counters.
//! Results append to `BENCH_serve.json` (schema `moe-gps/serve-bench/v1`)
//! and the CI bench-smoke copy gate validates the fraction the serve
//! report records.

use std::sync::Arc;

use moe_gps::bench::emit::{bench_json_path, record_serve_benches, ServeBenchRecord};
use moe_gps::bench::{black_box, group, Bencher};
use moe_gps::coordinator::request::RequestGen;
use moe_gps::coordinator::{Coordinator, ServeStrategy};
use moe_gps::runtime::HostTensor;
use moe_gps::util::rng::Rng;

/// The serving hot-path acceptance config (ISSUE 3): 8 virtual GPUs.
const E2E_WORKERS: usize = 8;

fn main() {
    group("fan-out: Arc share vs deep clone (8 workers, 256×256 f32)");
    let b = Bencher::default();
    let mut rng = Rng::new(9);
    let hidden = HostTensor::new(
        (0..256 * 256).map(|_| rng.normal() as f32).collect(),
        vec![256, 256],
    );
    let batch_bytes = (hidden.data.len() * 4) as f64;
    let shared = Arc::new(hidden.clone());
    let s = b.run("share_arc_x8", || {
        let fans: Vec<Arc<HostTensor>> = (0..8).map(|_| shared.clone()).collect();
        black_box(fans.len())
    });
    let share_s = s.median_s;
    let s = b.run("deep_clone_x8", || {
        let fans: Vec<HostTensor> = (0..8).map(|_| hidden.clone()).collect();
        black_box(fans.len())
    });
    if s.median_s > 0.0 && share_s > 0.0 {
        println!(
            "    sharing beats copying {:.0}× ({:.2} GB/s of clone traffic avoided)",
            s.median_s / share_s,
            8.0 * batch_bytes / s.median_s / 1e9
        );
    }

    group("slab build: pooled gather_rows_into vs fresh-alloc gather");
    let rows: Vec<usize> = (0..200).map(|i| (i * 7) % 256).collect();
    let gather_bytes = (rows.len() * 256 * 4) as f64;
    let mut slab: Vec<f32> = Vec::with_capacity(rows.len() * 256);
    let s = b.run("gather_into_slab_200_rows_d256", || {
        slab.clear();
        hidden.gather_rows_into(black_box(&rows), &mut slab);
        slab.len()
    });
    if s.median_s > 0.0 {
        println!("    gather bandwidth: {:.2} GB/s", gather_bytes / s.median_s / 1e9);
    }
    b.run("gather_fresh_alloc_200_rows_d256", || {
        hidden.gather_rows(black_box(&rows)).rows()
    });

    let artifacts = std::path::PathBuf::from("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("\nno AOT artifacts — E2E rounds run the synthetic tiny model");
    }

    group(&format!(
        "E2E data plane ({E2E_WORKERS} virtual GPUs, 2 seqs/round)"
    ));
    let quick = Bencher::quick();
    let mut records: Vec<ServeBenchRecord> = Vec::new();
    // (bench key, strategy, parallel attention fan-out)
    let legs = [
        ("dispatch_plane/round", ServeStrategy::NoPrediction, false),
        ("dispatch_plane/round", ServeStrategy::DistributionOnly, false),
        ("dispatch_plane/fanout", ServeStrategy::DistributionOnly, true),
    ];
    for (bench, strategy, fan_out) in legs {
        let mut coord = Coordinator::new(&artifacts, E2E_WORKERS, strategy).unwrap();
        coord.parallel_attention = fan_out;
        let mut gen = RequestGen::new(11, coord.vocab());
        let max_len = coord.seq_len();
        // Warmup: compile + teach estimators + warm the tile pool.
        let warm: Vec<_> = (0..2).map(|_| gen.request_varlen(64, max_len)).collect();
        coord.serve_round(&warm).unwrap();
        let reqs: Vec<_> = (0..2).map(|_| gen.request_varlen(64, max_len)).collect();
        let label = format!(
            "{}_{}{}",
            bench.rsplit('/').next().unwrap(),
            strategy.name(),
            if fan_out { "_fanout" } else { "" }
        );
        let summary = quick.bench(&label, || {
            coord.serve_round(black_box(&reqs)).unwrap().0.n_tokens
        });
        summary.print();
        // Data-plane stats from one measured round.
        let (m, _) = coord.serve_round(&reqs).unwrap();
        let tokens_per_s = if summary.median_s > 0.0 {
            m.n_tokens as f64 / summary.median_s
        } else {
            0.0
        };
        let copied_gbs = if summary.median_s > 0.0 {
            m.bytes_copied as f64 / summary.median_s / 1e9
        } else {
            0.0
        };
        let msgs_per_token = if m.n_tokens > 0 {
            m.ffn_messages as f64 / m.n_tokens as f64
        } else {
            0.0
        };
        println!(
            "    data plane: {:.1} tok/s | copied {} ({copied_gbs:.2} GB/s) | shared {} \
             | {} RunBatch msgs ({msgs_per_token:.3}/token, {} slots)",
            tokens_per_s,
            moe_gps::util::human_bytes(m.bytes_copied as f64),
            moe_gps::util::human_bytes(m.bytes_shared as f64),
            m.ffn_messages,
            m.n_slots,
        );
        records.push(ServeBenchRecord {
            bench: bench.into(),
            strategy: strategy.name().into(),
            lookahead: false,
            tokens_per_s,
            gbs: Some(copied_gbs),
            msgs_per_token: Some(msgs_per_token),
            ..Default::default()
        });
    }

    let path = bench_json_path();
    match record_serve_benches(&path, &records) {
        Ok(()) => println!("\nwrote {} records to {}", records.len(), path.display()),
        Err(err) => println!("\nWARN: could not write {}: {err}", path.display()),
    }
}
