//! Bench: **Table 1** — skewness vs Distribution-Only estimation error per
//! dataset (paper §3.2.1). Regenerates the table rows and micro-benchmarks
//! the estimator's hot paths.
//!
//! Paper reference:  MMLU 1.39 → 1.80% | Alpaca 1.40 → 0.98% | SST2 1.99 → 16%.

use moe_gps::bench::{black_box, group, Bencher};
use moe_gps::gps::calibrate::calibrate_all;
use moe_gps::gps::report;
use moe_gps::model::ModelConfig;
use moe_gps::predictor::distribution::DistributionEstimator;
use moe_gps::predictor::Predictor;
use moe_gps::sim::SystemSpec;
use moe_gps::trace::{datasets, Trace};

fn main() {
    let fast = std::env::var("MOE_GPS_FAST").is_ok();
    let model = ModelConfig::mixtral_8x7b();
    let system = SystemSpec::four_a100_nvlink();

    group("Table 1 — dataset skewness vs Distribution-Only error rate");
    let cals = calibrate_all(&model, &system, fast, 7);
    println!("{}", report::table1(&cals));
    println!("paper: mmlu 1.39/1.80%  alpaca 1.40/0.98%  sst2 1.99/16.00%");

    group("Table 1 micro-benchmarks");
    let b = Bencher::default();
    let trace = Trace::generate(datasets::mmlu_like(7));
    let counts: Vec<Vec<usize>> = trace
        .batches
        .iter()
        .map(|bt| bt.expert_counts(8))
        .collect();
    b.run("estimator_update_per_batch", || {
        let mut est = DistributionEstimator::new(8);
        for c in &counts {
            est.update(black_box(c));
        }
        est.mle()
    });
    let (train, test) = trace.split(0.8);
    let mut est = DistributionEstimator::new(8);
    est.fit(&train);
    b.run("error_rate_eval", || est.error_rate(black_box(&test)));
    b.run("trace_generation_mmlu_like", || {
        let mut spec = datasets::mmlu_like(9);
        spec.n_batches = 4;
        Trace::generate(spec).n_tokens()
    });
}
