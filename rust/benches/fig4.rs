//! Bench: **Figure 4** — Token-to-Expert predictor accuracy vs overhead vs
//! end-to-end normalized performance, on (a) MMLU/Alpaca-like skew ≈ 1.4
//! and (b) SST2-like skew ≈ 2.0 (paper §3.2.2).
//!
//! Expected shape: overhead grows ~exponentially in accuracy; normalized
//! performance peaks at an intermediate accuracy; at higher skewness the
//! same accuracy is cheaper (fit's exponent shrinks / accuracies rise).

use moe_gps::bench::{black_box, group, Bencher};
use moe_gps::gps::calibrate::{calibrate, CalibrationOptions};
use moe_gps::gps::report;
use moe_gps::model::ModelConfig;
use moe_gps::predictor::neural::{MlpConfig, MlpPredictor};
use moe_gps::predictor::Predictor;
use moe_gps::sim::SystemSpec;
use moe_gps::trace::{datasets, Trace};

fn main() {
    let fast = std::env::var("MOE_GPS_FAST").is_ok();
    let model = ModelConfig::mixtral_8x7b();
    let system = SystemSpec::four_a100_nvlink();
    let opts = CalibrationOptions {
        fast,
        ..Default::default()
    };

    group("Figure 4a — MMLU/Alpaca-like (skew ≈ 1.4)");
    for spec in [datasets::mmlu_like(7), datasets::alpaca_like(8)] {
        let cal = calibrate(spec, &model, &system, &opts);
        println!("{}", report::figure4(&cal));
    }

    group("Figure 4b — SST2-like (skew ≈ 2.0)");
    let cal_b = calibrate(datasets::sst2_like(9), &model, &system, &opts);
    println!("{}", report::figure4(&cal_b));
    println!(
        "paper check: higher skew → cheaper accuracy (smaller exponential \
         growth / higher accuracies at same predictor class)"
    );

    group("Figure 4 micro-benchmarks — predictor train/infer hot paths");
    let b = Bencher::default();
    let mut spec = datasets::mmlu_like(11);
    spec.n_batches = 8;
    spec.sequences_per_batch = 2;
    spec.seq_len = 128;
    spec.vocab_size = 512;
    let trace = Trace::generate(spec);
    let (train, test) = trace.split(0.8);
    b.run("mlp_fit_small_trace", || {
        let mut mlp = MlpPredictor::new(MlpConfig {
            epochs: 1,
            ..Default::default()
        });
        mlp.fit(black_box(&train));
        mlp.n_params()
    });
    let mut mlp = MlpPredictor::new(MlpConfig::default());
    mlp.fit(&train);
    b.run("mlp_predict_batch", || {
        mlp.predict_topk(black_box(&test.batches[0]), 1)
    });
}
