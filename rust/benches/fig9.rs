//! Bench: **Figure 9** — the Figure-6 experiment on Switch Transformer [7]
//! (paper Appendix C): ReLU experts, top-1 switch routing, MHA (no GQA).

use moe_gps::bench::group;
use moe_gps::gps::calibrate::calibrate_all;
use moe_gps::gps::sweep::{figure6_skews, skew_sweep};
use moe_gps::gps::{report, strategy_savings};
use moe_gps::model::ModelConfig;
use moe_gps::sim::SystemSpec;

fn main() {
    let fast = std::env::var("MOE_GPS_FAST").is_ok();
    let model = ModelConfig::switch_transformer();

    for (title, system) in [
        ("Figure 9a/9b — Switch Transformer, NVLink", SystemSpec::four_a100_nvlink()),
        ("Figure 9c/9d — Switch Transformer, PCIe", SystemSpec::four_a100_pcie()),
    ] {
        group(title);
        let cals = calibrate_all(&model, &system, fast, 31);
        let points = skew_sweep(&model, &system, &cals, &figure6_skews(), 1, 512);
        let kept: Vec<_> = points
            .into_iter()
            .filter(|p| {
                p.breakdown.overhead_s
                    <= 0.5 * p.total_s.max(p.breakdown.overhead_s + 1e-12)
            })
            .collect();
        println!("{}", report::figure6(&kept, title));
        let cmp = strategy_savings(&model, &system, &cals, 2.0, 1, 512);
        println!(
            "skew 2.0 on {}: DOP saving {:.3} ms vs best-TEP saving {:.3} ms",
            system.interconnect.name,
            cmp.dop_saving_s * 1e3,
            cmp.tep_best_saving_s * 1e3,
        );
    }
}
