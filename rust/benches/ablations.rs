//! Bench: **design-choice ablations** (DESIGN.md §5 calls these out).
//!
//! 1. DOP communication model: the paper says duplication leaves the
//!    all-to-all unchanged; how much would DOP gain if duplication also
//!    balanced the destinations?
//! 2. Prediction/placement frequency (§3.1): amortising TEP overhead over
//!    longer intervals moves its U-shape optimum toward higher accuracy.
//! 3. Duplication-transfer hiding (§5): charge vs hide the expert moves.
//! 4. Collective topology (§5): ring vs tree all-reduce, fully-connected
//!    vs mesh all-to-all.

use moe_gps::bench::group;
use moe_gps::model::ModelConfig;
use moe_gps::sim::collective::{
    ep_all_to_all_time, mesh_all_to_all_time, ring_allreduce_time, tree_allreduce_time,
};
use moe_gps::sim::moe::{moe_cost, MoeParams, Strategy};
use moe_gps::sim::SystemSpec;
use moe_gps::util::tablefmt::{f, Align, Table};

fn main() {
    let model = ModelConfig::mixtral_8x7b();

    group("Ablation 1 — DOP comm model: unchanged (paper) vs balanced");
    let mut t = Table::new(&["system", "skew", "unchanged (ms)", "balanced (ms)", "extra saving"])
        .align(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    for sys in [SystemSpec::four_a100_nvlink(), SystemSpec::four_a100_pcie()] {
        for &skew in &[1.4, 2.0, 4.0] {
            let mut p = MoeParams::new(1, 512, skew, Strategy::DistributionOnly { error_rate: 0.02 });
            let unchanged = moe_cost(&model, &sys, &p).total();
            p.dop_balanced_comm = true;
            let balanced = moe_cost(&model, &sys, &p).total();
            t.row(&[
                sys.interconnect.name.clone(),
                f(skew, 1),
                f(unchanged * 1e3, 3),
                f(balanced * 1e3, 3),
                format!("{:.1}%", (1.0 - balanced / unchanged) * 100.0),
            ]);
        }
    }
    println!("{}", t.render());
    println!("→ on PCIe the comm model choice matters a lot; on NVLink it is marginal.");

    group("Ablation 2 — prediction interval amortisation (TEP, PCIe, skew 2)");
    let sys = SystemSpec::four_a100_pcie();
    let mut t = Table::new(&["interval", "acc 0.7 (ms)", "acc 0.9 (ms)", "acc 0.99 (ms)", "best"])
        .align(&[Align::Right; 5]);
    for &interval in &[1usize, 4, 16, 64] {
        let mut cells = vec![interval.to_string()];
        let mut best = (0.0, f64::INFINITY);
        for &acc in &[0.7f64, 0.9, 0.99] {
            // Overhead envelope grows steeply with accuracy (Figure 4 fit).
            let overhead = 0.2e-3 * (6.0 * acc).exp() / 20.0;
            let mut p = MoeParams::new(1, 512, 2.0, Strategy::TokenToExpert { accuracy: acc, overhead_s: overhead });
            p.prediction_interval = interval;
            let total = moe_cost(&model, &sys, &p).total();
            if total < best.1 {
                best = (acc, total);
            }
            cells.push(f(total * 1e3, 3));
        }
        cells.push(f(best.0, 2));
        t.row(&cells);
    }
    println!("{}", t.render());
    println!("→ longer intervals shift the optimum toward higher accuracy (overhead amortised).");

    group("Ablation 3 — duplication-transfer hiding (§5)");
    let mut t = Table::new(&["system", "hidden (ms)", "charged (ms)"]).align(&[Align::Left, Align::Right, Align::Right]);
    for sys in [SystemSpec::four_a100_nvlink(), SystemSpec::four_a100_pcie()] {
        let attn = moe_gps::sim::attention::attention_cost(&model, &sys, 1, 512);
        let mut p = MoeParams::new(1, 512, 1.4, Strategy::DistributionOnly { error_rate: 0.02 });
        let hidden = moe_cost(&model, &sys, &p).total();
        p.hide_duplication = false;
        p.attention_compute_s = attn.compute();
        let charged = moe_cost(&model, &sys, &p).total();
        t.row(&[sys.interconnect.name.clone(), f(hidden * 1e3, 3), f(charged * 1e3, 3)]);
    }
    println!("{}", t.render());

    group("Ablation 4 — collective topologies (§5)");
    let ic = SystemSpec::four_a100_nvlink().interconnect;
    let bytes = 512.0 * 4096.0 * 2.0; // one layer's activations
    println!(
        "allreduce 4 GPUs, 4 MB: ring {} vs tree {}",
        moe_gps::util::human_time(ring_allreduce_time(&ic, 4, bytes)),
        moe_gps::util::human_time(tree_allreduce_time(&ic, 4, bytes)),
    );
    println!(
        "all-to-all 16 GPUs (1024 slots): fully-connected {} vs 2-D mesh {}",
        moe_gps::util::human_time(ep_all_to_all_time(&ic, 16, 1024.0, 8192.0, 1.4)),
        moe_gps::util::human_time(mesh_all_to_all_time(&ic, 16, 1024.0, 8192.0, 1.4)),
    );
    println!("→ topology scales the comm terms but leaves the strategy comparison intact (paper §5).");
}
