//! Offline mini-`anyhow`: the subset of the `anyhow` crate this project
//! uses, re-implemented with no dependencies so the repo builds without a
//! crates.io registry (the build image has no network — see DESIGN.md §6).
//!
//! Implemented surface: [`Error`], [`Result`], the [`Context`] trait
//! (`.context(...)` / `.with_context(...)` on `Result` and `Option`), and
//! the `anyhow!` / `bail!` / `ensure!` macros. `{err}` prints the outermost
//! message; `{err:#}` prints the whole context chain separated by `: `,
//! matching real anyhow's alternate formatting.

use std::fmt;

/// A context-chain error. `chain[0]` is the outermost (most recent)
/// message; the root cause is last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate the chain from outermost to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion from
// every std error type coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        // Preserve the source chain as context entries.
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — the crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Create an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn context_chain_formats() {
        let err: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{err}"), "reading manifest");
        assert_eq!(format!("{err:#}"), "reading manifest: no such file");
    }

    #[test]
    fn macros_compose() {
        fn inner(fail: bool) -> Result<u32> {
            ensure!(!fail, "failed with flag {}", fail);
            Ok(7)
        }
        assert_eq!(inner(false).unwrap(), 7);
        let err = inner(true).unwrap_err();
        assert_eq!(err.to_string(), "failed with flag true");
        let err2 = anyhow!("x = {}", 3);
        assert_eq!(err2.to_string(), "x = 3");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let err = none.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
        assert_eq!(Some(1u8).context("unused").unwrap(), 1);
    }

    #[test]
    fn with_context_lazy() {
        let err: Error = Err::<(), _>(io_err())
            .with_context(|| format!("step {}", 2))
            .unwrap_err();
        assert_eq!(format!("{err:#}"), "step 2: no such file");
    }
}
