//! Compile-only stub of the `xla` crate (PJRT C API bindings).
//!
//! The real PJRT backend needs `xla_extension`-based bindings that are not
//! present in every build image. This stub mirrors exactly the API surface
//! `moe_gps`'s `runtime::pjrt` module uses, so `--features pjrt` always
//! *compiles*; every entry point returns [`Error::Unavailable`] at runtime.
//! Build images that ship the real bindings replace the `vendor/xla` path
//! dependency (see DESIGN.md §6).

use std::fmt;

/// Stub error: the backend is not linked in this build.
#[derive(Debug)]
pub enum Error {
    Unavailable,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "PJRT backend unavailable: built against the stub `xla` crate \
             (vendor/xla); install the real xla bindings to execute artifacts"
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Marker for element types transferable to device buffers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable)
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable)
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::Unavailable)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable)
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}
