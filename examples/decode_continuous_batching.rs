//! Decode-phase continuous batching — the serving scenario production MoE
//! traffic actually lives in (DESIGN.md §4).
//!
//! Spins up the coordinator (AOT artifacts if built, else the synthetic
//! tiny model), queues a stream of requests, and serves them with
//! iteration-level admission/eviction under each prediction strategy:
//! one generated token per active sequence per step, per-step
//! Distribution-Only estimator updates, and Algorithm-1 replanning every
//! `--replan` steps (see docs/adr/001-decode-prediction-cadence.md).
//!
//! Run: `cargo run --release --example decode_continuous_batching`
//! Options: --workers 4 --seqs 8 --max-active 8 --prompt 32 --max-new 32
//!          --replan 4 --arrival-every 0 --seed 11 --artifacts <dir>

use moe_gps::coordinator::request::RequestGen;
use moe_gps::coordinator::{Coordinator, DecodeOptions, ServeStrategy};
use moe_gps::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let artifacts = std::path::PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let workers = args.opt_usize("workers", 4)?;
    let seqs = args.opt_usize("seqs", 8)?;
    let max_active = args.opt_usize("max-active", 8)?;
    let max_new = args.opt_usize("max-new", 32)?;
    let replan = args.opt_usize("replan", 4)?;
    let seed = args.opt_u64("seed", 11)?;

    println!(
        "continuous-batching decode: {seqs} requests, max {max_active} active, \
         {max_new} new tokens each, replan every {replan} steps\n"
    );

    for strategy in [
        ServeStrategy::NoPrediction,
        ServeStrategy::DistributionOnly,
        ServeStrategy::TokenToExpert,
    ] {
        let mut coord = Coordinator::new(&artifacts, workers, strategy)?;
        coord.placement.replan_interval = replan;
        let prompt = args.opt_usize("prompt", (coord.seq_len() / 8).max(4))?;
        let mut gen = RequestGen::new(seed, coord.vocab());
        let requests: Vec<_> = (0..seqs)
            .map(|_| gen.decode_request(prompt, max_new))
            .collect();
        let opts = DecodeOptions {
            max_active,
            max_steps: args.opt_usize("steps", 512)?,
            temperature: args.opt_f64("temperature", 1.0)?,
            seed,
            arrival_interval: args.opt_usize("arrival-every", 0)?,
        };
        let report = coord.serve_decode(requests, &opts)?;
        println!("{}", report.summary());
    }
    Ok(())
}
