//! End-to-end serving driver — the real system, not the simulator.
//!
//! Loads the AOT-compiled tiny Mixtral-style MoE (built by `make
//! artifacts`), spins up 4 virtual-GPU workers under Expert Parallelism,
//! and serves batched prefill requests under each prediction strategy,
//! reporting latency, throughput, and load imbalance. This is the
//! EXPERIMENTS.md §E2E run: it proves all three layers compose — Pallas
//! kernels (L1) inside JAX-lowered HLO (L2) executed from the rust
//! coordinator (L3) with dynamic expert duplication on the hot path.
//!
//! Run: `make artifacts && cargo run --release --example serve_moe`
//! Options: --workers 4 --rounds 10 --seqs 4 --seed 11 --artifacts <dir>

use moe_gps::coordinator::request::RequestGen;
use moe_gps::coordinator::{Batcher, Coordinator, ServeStrategy};
use moe_gps::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let artifacts = std::path::PathBuf::from(args.opt_or("artifacts", "artifacts"));
    if !artifacts.join("manifest.json").exists() {
        println!("no AOT artifacts found — serving the synthetic tiny model\n");
    }
    let workers = args.opt_usize("workers", 4)?;
    let n_rounds = args.opt_usize("rounds", 10)?;
    let seqs = args.opt_usize("seqs", 4)?;
    let seed = args.opt_u64("seed", 11)?;

    println!(
        "serving tiny-moe on {workers} virtual GPUs, {n_rounds} rounds × {seqs} seqs\n"
    );

    let mut results = Vec::new();
    for strategy in [
        ServeStrategy::NoPrediction,
        ServeStrategy::DistributionOnly,
        ServeStrategy::TokenToExpert,
    ] {
        let mut coord = Coordinator::new(&artifacts, workers, strategy)?;
        // Same workload for every strategy (fresh generator per run).
        let mut gen = RequestGen::new(seed, coord.vocab());
        let max_len = coord.seq_len();
        let mut batcher = Batcher::new(seqs, std::time::Duration::from_millis(5));
        for _ in 0..n_rounds * seqs {
            batcher.push(gen.request_varlen(max_len / 4, max_len));
        }
        let rounds = batcher.drain_rounds();
        // Warmup round compiles executables + teaches the DOP estimator.
        let report = coord.serve(rounds)?;
        println!("{}", report.summary());
        results.push((strategy, report));
    }

    // Cross-strategy comparison (steady-state rounds only: skip round 0,
    // which pays one-time compilation).
    println!("\nsteady-state comparison (rounds 2+):");
    for (strategy, report) in &results {
        let steady: Vec<_> = report.rounds.iter().skip(2).collect();
        let tokens: usize = steady.iter().map(|r| r.n_tokens).sum();
        let time: f64 = steady.iter().map(|r| r.total_s).sum();
        let imb: f64 = steady.iter().map(|r| r.slot_imbalance()).sum::<f64>()
            / steady.len().max(1) as f64;
        let skew: f64 = steady.iter().map(|r| r.routing_skew).sum::<f64>()
            / steady.len().max(1) as f64;
        println!(
            "  {:<18} {:>9.1} tok/s   slot imbalance {:.3}   routing skew {:.3}",
            strategy.name(),
            tokens as f64 / time,
            imb,
            skew,
        );
    }
    Ok(())
}
