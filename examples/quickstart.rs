//! Quickstart: the MoE-GPS core loop in ~40 lines.
//!
//! Simulates one Mixtral 8×7B layer on 4×A100/NVLink at the paper's main
//! operating point (batch 1, seq 512, skew 1.4) and asks the framework
//! which prediction strategy to use.
//!
//! Run: `cargo run --release --example quickstart`

use moe_gps::gps::{self, calibrate, CalibrationOptions};
use moe_gps::model::ModelConfig;
use moe_gps::sim::moe::Strategy;
use moe_gps::sim::{LayerSim, SystemSpec};
use moe_gps::trace::datasets;

fn main() -> anyhow::Result<()> {
    let model = ModelConfig::mixtral_8x7b();
    let system = SystemSpec::four_a100_nvlink();

    // 1. Price the baseline (no prediction) at MMLU-like skewness.
    let sim = LayerSim::new(model.clone(), system.clone());
    let skew = 1.4;
    let baseline = sim.breakdown(skew, Strategy::NoPrediction);
    println!("baseline single-layer prefill latency @ skew {skew}:");
    println!("{}", baseline.to_json().to_string_pretty());

    // 2. Calibrate the predictor zoo on an MMLU-like trace (fast mode).
    let opts = CalibrationOptions { fast: true, ..Default::default() };
    let cal = calibrate(datasets::mmlu_like(7), &model, &system, &opts);
    println!(
        "\nMMLU-like calibration: skew {:.2}, DOP error {:.2}%",
        cal.skewness,
        cal.dop_error * 100.0
    );

    // 3. Compare strategies and print the recommendation.
    let cmp = gps::strategy_savings(&model, &system, &[cal], skew, 1, 512);
    println!(
        "\nDistribution-Only saves {:.3} ms; best Token-to-Expert (acc {:.2}) saves {:.3} ms",
        cmp.dop_saving_s * 1e3,
        cmp.tep_best_accuracy,
        cmp.tep_best_saving_s * 1e3,
    );
    let rec = gps::select::recommend(&cmp);
    println!("MoE-GPS recommends: {}", rec.name());
    let improvement = (cmp.dop_saving_s - cmp.tep_best_saving_s)
        / (cmp.baseline_s - cmp.dop_saving_s);
    println!(
        "Distribution-Only end-to-end advantage over best Token-to-Expert: {:.1}%",
        improvement * 100.0
    );
    Ok(())
}
