//! GPS advisor: the Figure-1 guideline generator.
//!
//! Calibrates the predictor zoo on the three dataset emulators, sweeps a
//! (skewness × interconnect-bandwidth) grid, and prints the decision map +
//! prose guideline that Figure 1 of the paper summarises.
//!
//! Run: `cargo run --release --example gps_advisor [-- --fast]`

use moe_gps::gps::{calibrate, guidelines, CalibrationOptions};
use moe_gps::model::ModelConfig;
use moe_gps::sim::SystemSpec;
use moe_gps::trace::datasets;
use moe_gps::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["fast"]);
    let model = ModelConfig::by_name(args.opt_or("model", "mixtral-8x7b"))?;
    let opts = CalibrationOptions {
        fast: args.flag("fast"),
        ..Default::default()
    };
    // Overheads are priced per-system inside the sweep; calibrate the
    // accuracies once on the reference system.
    let reference = SystemSpec::four_a100_nvlink();
    println!("calibrating predictor zoo on 3 dataset emulators...");
    let cals: Vec<_> = datasets::all(args.opt_u64("seed", 7)?)
        .into_iter()
        .map(|spec| {
            let c = calibrate(spec, &model, &reference, &opts);
            println!(
                "  {:<12} skew {:.2}  DOP err {:.2}%  TEP accuracies {:?}",
                c.workload,
                c.skewness,
                c.dop_error * 100.0,
                c.points
                    .iter()
                    .map(|p| (p.accuracy * 100.0).round() / 100.0)
                    .collect::<Vec<_>>()
            );
            c
        })
        .collect();

    let skews = args.opt_f64_list("skews", &[1.0, 1.4, 2.0, 3.0, 4.0])?;
    let bandwidths =
        args.opt_f64_list("bandwidths", &[600.0, 300.0, 128.0, 64.0, 32.0])?;
    let cells = guidelines::decision_map(&model, &cals, &skews, &bandwidths, 1, 512);
    println!();
    println!("{}", guidelines::render_map(&cells, &skews, &bandwidths));
    println!("{}", guidelines::summarize(&cells));
    Ok(())
}
