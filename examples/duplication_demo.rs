//! Algorithm-1 walkthrough: dynamic expert duplication on the paper's
//! Figure-2 workload (expert 1 holding 75% of tokens, skewness 3) and on a
//! live synthetic trace, showing placement, dispatch and the §5 movement
//! cost analysis.
//!
//! Run: `cargo run --release --example duplication_demo`

use moe_gps::duplication::algorithm::balance;
use moe_gps::duplication::cost::movement_report;
use moe_gps::duplication::dispatch::dispatch_with_quota;
use moe_gps::duplication::Placement;
use moe_gps::model::ModelConfig;
use moe_gps::sim::SystemSpec;
use moe_gps::trace::{datasets, Trace};
use moe_gps::util::stats;

fn main() {
    // --- Paper Figure 2: 4 experts / 4 GPUs, expert 0 has 75% ------------
    println!("== Figure 2 workload: expert 0 holds 75% of 1024 tokens ==");
    let tokens = [768usize, 96, 80, 80];
    let initial = Placement::initial(4, 4, 4, 4);
    println!("before: loads {:?}  skew {:.2}", tokens, 768.0 / 256.0);
    let result = balance(&tokens, &initial);
    println!(
        "after Algorithm 1: loads {:?}  skew {:.3}  ({} iterations, converged={})",
        result.loads,
        result.skewness(),
        result.iterations,
        result.converged
    );
    for e in 0..4 {
        println!(
            "  expert {e}: {} cop{} on GPUs {:?}",
            result.placement.copies(e),
            if result.placement.copies(e) == 1 { "y" } else { "ies" },
            result.placement.gpus_of(e)
        );
    }

    // --- Live trace: plan on layer counts, dispatch with quotas ----------
    println!("\n== SST2-like batch (skew ~2) through plan + dispatch ==");
    let trace = Trace::generate(datasets::sst2_like(3));
    let batch = &trace.batches[0];
    let counts = batch.expert_counts(8);
    println!("routed counts: {counts:?}  skew {:.3}", batch.skewness(8));
    let initial = Placement::initial(8, 4, 8, 4);
    let plan = balance(&counts, &initial);
    println!(
        "plan: {} replicas added, post-balance skew {:.3}",
        initial.added_replicas(&plan.placement).len(),
        plan.skewness()
    );
    let experts: Vec<u8> = batch
        .sequences
        .iter()
        .flatten()
        .map(|t| t.expert)
        .collect();
    let (_assign, loads) = dispatch_with_quota(&experts, &plan.placement, &plan.share);
    println!(
        "dispatched per-GPU loads: {loads:?}  skew {:.3}",
        stats::skewness_of_counts(&loads)
    );

    // --- §5 movement-cost analysis ---------------------------------------
    println!("\n== §5: can the expert transfer hide under attention? ==");
    let model = ModelConfig::mixtral_8x7b();
    for sys in [SystemSpec::four_a100_nvlink(), SystemSpec::four_a100_pcie()] {
        for (b, s) in [(1usize, 512usize), (16, 2048)] {
            let r = movement_report(&model, &sys, b, s, 1);
            println!(
                "  {:<11} bs={b:<3} seq={s:<5} transfer {:>9}  attention {:>9}  {}",
                sys.interconnect.name,
                moe_gps::util::human_time(r.transfer_s),
                moe_gps::util::human_time(r.attention_compute_s),
                if r.hidden {
                    "hidden".to_string()
                } else {
                    format!("EXPOSED {}", moe_gps::util::human_time(r.exposed_s))
                }
            );
        }
    }
}
