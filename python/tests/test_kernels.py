"""L1 correctness: Pallas kernels vs pure-jnp references.

The hypothesis sweeps cover shapes/dtypes/seeds as DESIGN.md §7 requires;
the fixed-shape tests pin the exact configurations the AOT artifacts use.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.moe_ffn import swiglu_ffn, vmem_bytes, T_TILE, F_TILE
from compile.kernels.ref import (
    rmsnorm_ref,
    router_logits_ref,
    silu_ref,
    swiglu_ffn_ref,
)
from compile.kernels.router_topk import router


def rand(rng, shape, scale=0.05, dtype=jnp.float32):
    return jnp.asarray(rng.normal(0.0, scale, size=shape), dtype)


# ---------------------------------------------------------------------------
# SwiGLU FFN kernel
# ---------------------------------------------------------------------------

class TestSwigluKernel:
    @pytest.mark.parametrize("tokens", [64, 128, 256, 512])
    def test_matches_ref_at_artifact_buckets(self, tokens):
        rng = np.random.default_rng(tokens)
        d, f = 256, 512
        x = rand(rng, (tokens, d), 1.0)
        wg, wu = rand(rng, (d, f)), rand(rng, (d, f))
        wd = rand(rng, (f, d))
        out = swiglu_ffn(x, wg, wu, wd)
        assert_allclose(out, swiglu_ffn_ref(x, wg, wu, wd), rtol=2e-5, atol=2e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        t_mult=st.integers(1, 4),
        f_mult=st.integers(1, 3),
        d=st.sampled_from([64, 128, 256]),
        seed=st.integers(0, 2**31 - 1),
        scale=st.sampled_from([0.02, 0.3, 1.5]),
    )
    def test_hypothesis_shape_sweep(self, t_mult, f_mult, d, seed, scale):
        rng = np.random.default_rng(seed)
        t, f = t_mult * T_TILE, f_mult * F_TILE
        x = rand(rng, (t, d), scale)
        wg, wu = rand(rng, (d, f), scale), rand(rng, (d, f), scale)
        wd = rand(rng, (f, d), scale)
        out = swiglu_ffn(x, wg, wu, wd)
        ref = swiglu_ffn_ref(x, wg, wu, wd)
        tol = 1e-4 * max(1.0, float(jnp.max(jnp.abs(ref))))
        assert_allclose(out, ref, rtol=1e-4, atol=tol)

    @settings(max_examples=8, deadline=None)
    @given(
        t_tile=st.sampled_from([32, 64, 128]),
        f_tile=st.sampled_from([128, 256, 512]),
    )
    def test_tile_size_invariance(self, t_tile, f_tile):
        """Any legal tiling must produce identical results (the perf pass
        tunes tiles; numerics must not change)."""
        rng = np.random.default_rng(9)
        t, d, f = 128, 128, 512
        x = rand(rng, (t, d), 0.5)
        wg, wu, wd = rand(rng, (d, f)), rand(rng, (d, f)), rand(rng, (f, d))
        base = swiglu_ffn(x, wg, wu, wd, t_tile=64, f_tile=256)
        other = swiglu_ffn(x, wg, wu, wd, t_tile=t_tile, f_tile=f_tile)
        assert_allclose(base, other, rtol=2e-5, atol=2e-5)

    def test_rejects_unaligned_tokens(self):
        rng = np.random.default_rng(0)
        with pytest.raises(AssertionError):
            swiglu_ffn(
                rand(rng, (65, 256)),
                rand(rng, (256, 512)),
                rand(rng, (256, 512)),
                rand(rng, (512, 256)),
            )

    def test_vmem_estimate_under_budget(self):
        # DESIGN.md §Perf: one grid step must fit VMEM (≈16 MiB) with room
        # for double buffering.
        assert vmem_bytes() < 8 * 1024 * 1024

    def test_zero_input_gives_zero_output(self):
        d, f = 256, 512
        x = jnp.zeros((64, d))
        rng = np.random.default_rng(1)
        out = swiglu_ffn(x, rand(rng, (d, f)), rand(rng, (d, f)), rand(rng, (f, d)))
        assert float(jnp.max(jnp.abs(out))) == 0.0


# ---------------------------------------------------------------------------
# Router kernel
# ---------------------------------------------------------------------------

class TestRouterKernel:
    def test_matches_ref_at_artifact_shape(self):
        rng = np.random.default_rng(3)
        s, d, e = 256, 256, 8
        x = rand(rng, (s, d), 1.0)
        lnw = jnp.asarray(rng.uniform(0.5, 1.5, d), jnp.float32)
        wr = rand(rng, (d, e), 0.2)
        xn, logits = router(x, lnw, wr)
        xn_ref = rmsnorm_ref(x, lnw)
        assert_allclose(xn, xn_ref, rtol=2e-5, atol=2e-5)
        assert_allclose(logits, router_logits_ref(xn_ref, wr), rtol=2e-5, atol=2e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        s_mult=st.integers(1, 4),
        d=st.sampled_from([64, 128, 256]),
        e=st.sampled_from([4, 8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, s_mult, d, e, seed):
        rng = np.random.default_rng(seed)
        s = 64 * s_mult
        x = rand(rng, (s, d), 0.7)
        lnw = jnp.ones((d,), jnp.float32)
        wr = rand(rng, (d, e), 0.3)
        xn, logits = router(x, lnw, wr)
        xn_ref = rmsnorm_ref(x, lnw)
        assert_allclose(xn, xn_ref, rtol=1e-4, atol=1e-5)
        assert_allclose(logits, xn_ref @ wr, rtol=1e-4, atol=1e-5)

    def test_argmax_agrees_with_ref(self):
        """Routing decisions (what the coordinator consumes) must agree."""
        rng = np.random.default_rng(5)
        s, d, e = 256, 256, 8
        x = rand(rng, (s, d), 1.0)
        lnw = jnp.ones((d,), jnp.float32)
        wr = rand(rng, (d, e), 0.3)
        _, logits = router(x, lnw, wr)
        ref_logits = rmsnorm_ref(x, lnw) @ wr
        assert (jnp.argmax(logits, -1) == jnp.argmax(ref_logits, -1)).all()


# ---------------------------------------------------------------------------
# Reference self-checks
# ---------------------------------------------------------------------------

def test_silu_matches_jax_nn():
    x = jnp.linspace(-6, 6, 101)
    assert_allclose(silu_ref(x), jax.nn.silu(x), rtol=1e-6, atol=1e-6)


def test_rmsnorm_unit_variance():
    rng = np.random.default_rng(11)
    x = rand(rng, (32, 128), 3.0)
    out = rmsnorm_ref(x, jnp.ones(128))
    ms = jnp.mean(jnp.square(out), axis=-1)
    assert_allclose(ms, jnp.ones_like(ms), rtol=1e-3)
