"""L2 correctness: tiny-MoE model pieces, predictor, and AOT contract."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def weights():
    return M.init_weights(seed=0)


@pytest.fixture(scope="module")
def jweights(weights):
    return {k: jnp.asarray(v) for k, v in weights.items()}


def test_weight_shapes_match_config(weights):
    cfg = M.TINY_CONFIG
    d = cfg["d_model"]
    assert weights["embed"].shape == (cfg["vocab_size"], d)
    for l in range(cfg["n_layers"]):
        assert weights[f"layers.{l}.moe.router"].shape == (d, cfg["n_experts"])
        for e in range(cfg["n_experts"]):
            assert weights[f"layers.{l}.experts.{e}.w_gate"].shape == (
                d,
                cfg["d_ff"],
            )


def test_attention_block_shapes_and_residual(jweights):
    cfg = M.TINY_CONFIG
    s, d = cfg["seq_len"], cfg["d_model"]
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 0.3, (s, d)), jnp.float32)
    out = M.attention_block_fn(
        x,
        *(jweights[f"layers.0.attn.{k}"] for k in ("ln", "wq", "wk", "wv", "wo")),
    )
    assert out.shape == (s, d)
    # Residual: output correlates strongly with input.
    corr = float(
        jnp.sum(out * x) / (jnp.linalg.norm(out) * jnp.linalg.norm(x))
    )
    assert corr > 0.5, corr


def test_attention_is_causal(jweights):
    """Changing a future token must not affect earlier positions."""
    cfg = M.TINY_CONFIG
    s, d = 64, cfg["d_model"]
    rng = np.random.default_rng(2)
    x = np.asarray(rng.normal(0, 0.3, (s, d)), np.float32)
    args = [jweights[f"layers.0.attn.{k}"] for k in ("ln", "wq", "wk", "wv", "wo")]
    # NOTE: attention_block_fn is shape-generic; use seq 64 here.
    out1 = M.attention_block_fn(jnp.asarray(x), *args)
    x2 = x.copy()
    x2[-1] += 5.0
    out2 = M.attention_block_fn(jnp.asarray(x2), *args)
    assert_allclose(out1[:-1], out2[:-1], rtol=1e-5, atol=1e-6)
    assert float(jnp.max(jnp.abs(out1[-1] - out2[-1]))) > 1e-3


def test_model_forward_shapes_and_routing(jweights):
    cfg = M.TINY_CONFIG
    rng = np.random.default_rng(3)
    ids = jnp.asarray(
        rng.integers(0, cfg["vocab_size"], (1, cfg["seq_len"])), jnp.int32
    )
    hidden, routes = M.model_forward_ref(ids, jweights)
    assert hidden.shape == (cfg["seq_len"], cfg["d_model"])
    assert routes.shape == (cfg["n_layers"], cfg["seq_len"], cfg["top_k"])
    assert int(routes.min()) >= 0 and int(routes.max()) < cfg["n_experts"]
    # Top-k experts must be distinct per token.
    assert bool((routes[..., 0] != routes[..., 1]).all())


def test_routing_is_skewed_and_token_driven(jweights):
    """The properties the paper's machinery needs from a serving model."""
    cfg = M.TINY_CONFIG
    rng = np.random.default_rng(4)
    skews = []
    ids = jnp.asarray(
        rng.integers(0, cfg["vocab_size"], (1, cfg["seq_len"])), jnp.int32
    )
    _, routes = M.model_forward_ref(ids, jweights)
    for l in range(cfg["n_layers"]):
        counts = np.bincount(np.asarray(routes[l, :, 0]), minlength=8)
        skews.append(counts.max() / counts.mean())
    assert max(skews) > 1.3, f"routing should be skewed, got {skews}"


def test_moe_block_gates_sum_to_one(jweights):
    cfg = M.TINY_CONFIG
    rng = np.random.default_rng(5)
    h = jnp.asarray(rng.normal(0, 0.3, (64, cfg["d_model"])), jnp.float32)
    out, top_idx = M.moe_block_ref(h, jweights, 0)
    assert out.shape == h.shape
    assert top_idx.shape == (64, cfg["top_k"])


def test_predictor_forward_shape(jweights, weights):
    cfg = M.TINY_CONFIG
    pw = M.init_predictor_weights()
    rng = np.random.default_rng(6)
    x0 = jnp.asarray(
        rng.normal(0, 0.3, (cfg["seq_len"], cfg["d_model"])), jnp.float32
    )
    logits = M.predictor_fn(
        x0,
        jnp.asarray(pw["predictor.w1"]),
        jnp.asarray(pw["predictor.b1"]),
        *[
            jnp.asarray(pw[f"predictor.head.{l}"])
            for l in range(cfg["n_layers"])
        ],
    )
    assert logits.shape == (cfg["n_layers"], cfg["seq_len"], cfg["n_experts"])


@pytest.mark.slow
def test_predictor_learns_above_chance(weights):
    pw, acc = M.train_predictor(weights, steps=80, batch_seqs=2)
    assert acc > 0.18, f"predictor should beat 1/8 chance, got {acc}"


# ---------------------------------------------------------------------------
# AOT artifact contract (requires `make artifacts` to have run)
# ---------------------------------------------------------------------------

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@needs_artifacts
def test_manifest_lists_all_artifacts():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    names = set(manifest["artifacts"].keys())
    expected = {"embed", "attention", "router", "predictor"} | {
        f"expert_ffn_b{b}" for b in M.TINY_CONFIG["ffn_buckets"]
    }
    assert expected <= names, names
    for art in manifest["artifacts"].values():
        assert os.path.exists(os.path.join(ARTIFACTS, art["file"]))
    # Weight payload is complete and the offsets are consistent.
    total = os.path.getsize(os.path.join(ARTIFACTS, "weights.bin"))
    end = max(
        w["offset"] + 4 * int(np.prod(w["shape"]))
        for w in manifest["weights"].values()
    )
    assert end == total


@needs_artifacts
def test_hlo_text_is_parseable_prefix():
    # HLO text artifacts must start with the module header the rust loader
    # (HloModuleProto::from_text_file) expects.
    for name in ["attention", "router", "expert_ffn_b64"]:
        with open(os.path.join(ARTIFACTS, f"{name}.hlo.txt")) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), head[:40]


@needs_artifacts
def test_oracle_matches_recomputed_weights():
    """weights.bin + manifest must reproduce init_weights(seed=0) exactly."""
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    blob = np.fromfile(os.path.join(ARTIFACTS, "weights.bin"), "<f4")
    w = M.init_weights(seed=0)
    for name in ["embed", "layers.0.moe.router", "final.ln"]:
        meta = manifest["weights"][name]
        n = int(np.prod(meta["shape"]))
        stored = blob[meta["offset"] // 4 : meta["offset"] // 4 + n].reshape(
            meta["shape"]
        )
        assert_allclose(stored, w[name], rtol=0, atol=0)
