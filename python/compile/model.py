"""Layer 2 — the tiny Mixtral-style MoE transformer served end-to-end.

Build-time only: this module defines the model pieces (embedding, GQA
attention block, fused router kernel, Pallas expert FFN), the weight
initialiser, a dense full-model reference (the numerics oracle for the
rust integration tests), and the token-to-expert FFN *predictor* that the
paper's Token-to-Expert strategy needs — trained here, AOT-compiled by
``aot.py``, executed from rust through PJRT. Python never runs on the
request path.

Must stay in sync with ``rust/src/model/mod.rs::ModelConfig::tiny_serve``.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.moe_ffn import swiglu_ffn
from .kernels.ref import rmsnorm_ref, swiglu_ffn_ref
from .kernels.router_topk import router as router_kernel

TINY_CONFIG = {
    "name": "tiny-moe-serve",
    "d_model": 256,
    "n_heads": 8,
    "n_kv_heads": 2,
    "head_dim": 32,
    "d_ff": 512,
    "n_experts": 8,
    "top_k": 2,
    "n_layers": 4,
    "vocab_size": 4096,
    # Fixed prefill bucket the attention/router artifacts are compiled for.
    "seq_len": 256,
    # Token-count buckets the expert-FFN artifact is compiled for.
    "ffn_buckets": [16, 32, 64, 128, 256, 512],
}

# Predictor architecture (a scaled-down version of the paper's Appendix-B
# FFN predictor: token embedding -> 128 -> ReLU -> per-layer expert heads).
PREDICTOR_HIDDEN = 128


# --------------------------------------------------------------------------
# Weights
# --------------------------------------------------------------------------

def init_weights(seed=0, cfg=TINY_CONFIG):
    """Deterministic weight set as a flat {name: np.float32 array} dict.

    The rust runtime loads these from artifacts/weights.bin via the
    manifest; names are the contract.
    """
    rng = np.random.default_rng(seed)
    d = cfg["d_model"]
    hd = cfg["head_dim"]
    nh = cfg["n_heads"]
    nkv = cfg["n_kv_heads"]
    ff = cfg["d_ff"]
    e = cfg["n_experts"]

    def normal(shape, scale):
        return rng.normal(0.0, scale, size=shape).astype(np.float32)

    w = {"embed": normal((cfg["vocab_size"], d), 0.3)}
    # Routers are *embedding-anchored*: each expert's router column points
    # toward the embeddings of a cluster of anchor tokens, and columns get
    # a mild geometric scale. This gives the tiny model the two properties
    # the paper observes in real MoEs and everything downstream relies on:
    # token-identity-driven routing (predictable — Figure 4) and a skewed
    # expert distribution (imbalanced — skewness ≈ 1.4–2).
    col_scale = (1.15 ** -np.arange(e)).astype(np.float32)
    for l in range(cfg["n_layers"]):
        p = f"layers.{l}"
        w[f"{p}.attn.ln"] = np.ones((d,), np.float32)
        w[f"{p}.attn.wq"] = normal((d, nh * hd), d**-0.5)
        w[f"{p}.attn.wk"] = normal((d, nkv * hd), d**-0.5)
        w[f"{p}.attn.wv"] = normal((d, nkv * hd), d**-0.5)
        w[f"{p}.attn.wo"] = normal((nh * hd, d), 0.1 * (nh * hd) ** -0.5)
        w[f"{p}.moe.ln"] = np.ones((d,), np.float32)
        anchor_ids = rng.integers(0, cfg["vocab_size"], size=e)
        anchors = w["embed"][anchor_ids].T.copy()  # [d, e]
        anchors /= np.linalg.norm(anchors, axis=0, keepdims=True) + 1e-8
        w[f"{p}.moe.router"] = (
            (anchors * 4.0 + normal((d, e), 0.02)) * col_scale[None, :]
        ).astype(np.float32)
        for x in range(e):
            w[f"{p}.experts.{x}.w_gate"] = normal((d, ff), d**-0.5)
            w[f"{p}.experts.{x}.w_up"] = normal((d, ff), d**-0.5)
            w[f"{p}.experts.{x}.w_down"] = normal((ff, d), ff**-0.5)
    w["final.ln"] = np.ones((d,), np.float32)
    return w


# --------------------------------------------------------------------------
# Model pieces (each becomes one AOT artifact; weights are arguments)
# --------------------------------------------------------------------------

def embed_fn(ids, embed):
    """ids [1, S] int32, embed [V, D] -> activations [S, D]."""
    return embed[ids[0]]


def attention_block_fn(x, ln, wq, wk, wv, wo, cfg=TINY_CONFIG):
    """Pre-norm causal GQA attention with residual: ``x + attn(norm(x))``.

    x [S, D] -> [S, D].
    """
    nh, nkv, hd = cfg["n_heads"], cfg["n_kv_heads"], cfg["head_dim"]
    s, d = x.shape
    xn = rmsnorm_ref(x, ln)
    q = (xn @ wq).reshape(s, nh, hd)
    k = (xn @ wk).reshape(s, nkv, hd)
    v = (xn @ wv).reshape(s, nkv, hd)
    # GQA: repeat kv heads across the query groups.
    group = nh // nkv
    k = jnp.repeat(k, group, axis=1)  # [S, nh, hd]
    v = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(jnp.float32(hd))
    causal = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(causal[None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hqk,khd->qhd", probs, v).reshape(s, nh * hd)
    return x + ctx @ wo


def router_block_fn(h, ln, w_router):
    """Fused RMSNorm + router logits via the Pallas kernel.

    h [S, D] -> (normed [S, D], logits [S, E]). Top-k selection happens in
    the rust coordinator.
    """
    return router_kernel(h, ln, w_router)


def expert_ffn_fn(xn, w_gate, w_up, w_down):
    """One expert's SwiGLU FFN over a routed token slice (Pallas kernel).

    xn [T, D] -> [T, D] (no residual — the coordinator gates and combines).
    Small buckets (< the default 64-row tile) shrink the token tile to the
    bucket size — still MXU-shaped on the reduction/ff axes.
    """
    t_tile = min(64, xn.shape[0])
    return swiglu_ffn(xn, w_gate, w_up, w_down, t_tile=t_tile)


# --------------------------------------------------------------------------
# Dense reference forward (numerics oracle; all experts computed densely)
# --------------------------------------------------------------------------

def moe_block_ref(h, weights, layer, cfg=TINY_CONFIG):
    """Dense-MoE reference: softmax top-k gating over all experts."""
    p = f"layers.{layer}"
    xn = rmsnorm_ref(h, weights[f"{p}.moe.ln"])
    logits = xn @ weights[f"{p}.moe.router"]
    k = cfg["top_k"]
    # Top-k gates (softmax over the selected logits, Mixtral-style).
    top_vals, top_idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(top_vals, axis=-1)  # [S, k]
    out = h
    for e in range(cfg["n_experts"]):
        expert_out = swiglu_ffn_ref(
            xn,
            weights[f"{p}.experts.{e}.w_gate"],
            weights[f"{p}.experts.{e}.w_up"],
            weights[f"{p}.experts.{e}.w_down"],
        )
        weight_e = jnp.sum(jnp.where(top_idx == e, gates, 0.0), axis=-1)
        out = out + weight_e[:, None] * expert_out
    return out, top_idx


def model_forward_ref(ids, weights, cfg=TINY_CONFIG):
    """Full-model reference prefill.

    ids [1, S] -> (hidden [S, D], routing [L, S, k] expert indices).
    """
    h = embed_fn(ids, weights["embed"])
    routes = []
    for l in range(cfg["n_layers"]):
        p = f"layers.{l}"
        h = attention_block_fn(
            h,
            weights[f"{p}.attn.ln"],
            weights[f"{p}.attn.wq"],
            weights[f"{p}.attn.wk"],
            weights[f"{p}.attn.wv"],
            weights[f"{p}.attn.wo"],
            cfg,
        )
        h, top_idx = moe_block_ref(h, weights, l, cfg)
        routes.append(top_idx)
    h = rmsnorm_ref(h, weights["final.ln"])
    return h, jnp.stack(routes)


# --------------------------------------------------------------------------
# Token-to-expert predictor (paper Appendix B, FFN variant)
# --------------------------------------------------------------------------

def init_predictor_weights(seed=1, cfg=TINY_CONFIG):
    rng = np.random.default_rng(seed)
    d = cfg["d_model"]
    h = PREDICTOR_HIDDEN
    w = {
        "predictor.w1": rng.normal(0, (2.0 / d) ** 0.5, (d, h)).astype(np.float32),
        "predictor.b1": np.zeros((h,), np.float32),
    }
    for l in range(cfg["n_layers"]):
        w[f"predictor.head.{l}"] = rng.normal(
            0, (2.0 / h) ** 0.5, (h, cfg["n_experts"])
        ).astype(np.float32)
    return w


def predictor_fn(x0, w1, b1, *heads):
    """Predict every layer's expert logits from the embedded tokens.

    x0 [S, D] (embedding output, pre-attention) -> [L, S, E]. This is what
    lets the coordinator plan duplication for *all* layers before the first
    attention runs (paper §3.1 inserts the predictor before Attention).
    """
    hidden = jax.nn.relu(x0 @ w1 + b1)
    return jnp.stack([hidden @ h for h in heads])


def train_predictor(weights, steps=300, batch_seqs=8, seed=3, lr=3e-3,
                    cfg=TINY_CONFIG, verbose=False):
    """Train the predictor on the tiny model's own routing decisions.

    Generates random token batches, runs the reference model to obtain the
    ground-truth top-1 expert per (layer, token), and fits the predictor
    with plain Adam on cross-entropy (the paper's Appendix-B recipe).
    Returns (predictor weight dict, final accuracy on a held-out batch).
    """
    rng = np.random.default_rng(seed)
    pw = init_predictor_weights(seed=seed + 1, cfg=cfg)
    names = sorted(pw.keys())
    s = cfg["seq_len"]
    n_layers = cfg["n_layers"]

    jweights = {k: jnp.asarray(val) for k, val in weights.items()}
    fwd = jax.jit(lambda ids: model_forward_ref(ids, jweights, cfg))

    def make_batch():
        ids = rng.integers(0, cfg["vocab_size"], size=(batch_seqs, 1, s)).astype(
            np.int32
        )
        xs, labels = [], []
        for b in range(batch_seqs):
            _, routes = fwd(jnp.array(ids[b]))
            xs.append(weights["embed"][ids[b, 0]])
            labels.append(np.array(routes[:, :, 0]))  # top-1 expert [L, S]
        return (
            jnp.array(np.stack(xs)),  # [B, S, D]
            jnp.array(np.stack(labels)),  # [B, L, S]
        )

    def loss_fn(params, x0, labels):
        w1, b1 = params["predictor.w1"], params["predictor.b1"]
        heads = [params[f"predictor.head.{l}"] for l in range(n_layers)]
        hidden = jax.nn.relu(x0 @ w1 + b1)  # [B, S, H]
        logits = jnp.stack([hidden @ h for h in heads], axis=1)  # [B, L, S, E]
        logp = jax.nn.log_softmax(logits, axis=-1)
        onehot = jax.nn.one_hot(labels, cfg["n_experts"])
        return -jnp.mean(jnp.sum(onehot * logp, axis=-1))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    # Plain Adam (optax is unavailable offline).
    m = {k: np.zeros_like(v) for k, v in pw.items()}
    v = {k: np.zeros_like(val) for k, val in pw.items()}
    b1m, b2m = 0.9, 0.999
    x_val, y_val = make_batch()
    x_tr, y_tr = make_batch()
    for t in range(1, steps + 1):
        if t % 50 == 0:
            x_tr, y_tr = make_batch()
        loss, grads = grad_fn(pw, x_tr, y_tr)
        for k in names:
            g = np.array(grads[k])
            m[k] = b1m * m[k] + (1 - b1m) * g
            v[k] = b2m * v[k] + (1 - b2m) * g * g
            mh = m[k] / (1 - b1m**t)
            vh = v[k] / (1 - b2m**t)
            pw[k] = np.asarray(pw[k] - lr * mh / (np.sqrt(vh) + 1e-8), np.float32)
        if verbose and t % 50 == 0:
            print(f"  predictor step {t}: loss {float(loss):.4f}")

    # Held-out accuracy.
    heads = [pw[f"predictor.head.{l}"] for l in range(n_layers)]
    hidden = jax.nn.relu(x_val @ pw["predictor.w1"] + pw["predictor.b1"])
    logits = jnp.stack([hidden @ h for h in heads], axis=1)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == y_val))
    return pw, acc
