"""AOT compilation: lower every model piece to HLO **text** + pack weights.

Run once by ``make artifacts``; the rust binary is self-contained after.

Interchange format is HLO text, NOT ``lowered.compile()``/``.serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Outputs (artifacts/):
  manifest.json           artifact + weight index (shapes, offsets, dtypes)
  weights.bin             all weights, little-endian f32, concatenated
  embed.hlo.txt           (ids i32[1,S], embed f32[V,D]) -> x f32[S,D]
  attention.hlo.txt       (x, ln, wq, wk, wv, wo) -> h (residual inside)
  router.hlo.txt          (h, ln, w_router) -> (xn, logits)   [Pallas]
  expert_ffn_b{N}.hlo.txt (xn[N,D], w_gate, w_up, w_down) -> out  [Pallas]
  predictor.hlo.txt       (x0, w1, b1, head0..headL) -> logits [L,S,E]
  oracle.json             reference inputs/outputs for rust integration
                          tests (prefix values of each artifact's output
                          plus the full-model forward).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(fn, *specs):
    """Lower a jax function to HLO text via stablehlo -> XlaComputation."""
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--outdir", default="../artifacts")
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument(
        "--predictor-steps", type=int, default=200,
        help="Adam steps for the token-to-expert predictor",
    )
    parser.add_argument("--skip-predictor-training", action="store_true")
    args = parser.parse_args()
    cfg = M.TINY_CONFIG
    outdir = args.outdir
    os.makedirs(outdir, exist_ok=True)

    d, s, v = cfg["d_model"], cfg["seq_len"], cfg["vocab_size"]
    nh, nkv, hd = cfg["n_heads"], cfg["n_kv_heads"], cfg["head_dim"]
    ff, e, n_layers = cfg["d_ff"], cfg["n_experts"], cfg["n_layers"]

    print(f"[aot] initialising weights (seed {args.seed})")
    weights = M.init_weights(seed=args.seed, cfg=cfg)

    print("[aot] training token-to-expert predictor "
          f"({args.predictor_steps} steps)")
    if args.skip_predictor_training:
        pweights, pred_acc = M.init_predictor_weights(cfg=cfg), 0.0
    else:
        pweights, pred_acc = M.train_predictor(
            weights, steps=args.predictor_steps, cfg=cfg, verbose=True
        )
        print(f"[aot] predictor held-out top-1 accuracy: {pred_acc:.3f}")
    weights.update(pweights)

    artifacts = {}

    def emit(name, fn, *specs):
        path = os.path.join(outdir, f"{name}.hlo.txt")
        text = to_hlo_text(fn, *specs)
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [list(sp.shape) for sp in specs],
        }
        print(f"[aot] wrote {path} ({len(text)} chars)")

    # --- model pieces -----------------------------------------------------
    emit("embed", M.embed_fn, i32((1, s)), f32((v, d)))
    emit(
        "attention",
        lambda x, ln, wq, wk, wv, wo: M.attention_block_fn(
            x, ln, wq, wk, wv, wo, cfg
        ),
        f32((s, d)), f32((d,)), f32((d, nh * hd)), f32((d, nkv * hd)),
        f32((d, nkv * hd)), f32((nh * hd, d)),
    )
    emit(
        "router",
        M.router_block_fn,
        f32((s, d)), f32((d,)), f32((d, e)),
    )
    for bucket in cfg["ffn_buckets"]:
        emit(
            f"expert_ffn_b{bucket}",
            M.expert_ffn_fn,
            f32((bucket, d)), f32((d, ff)), f32((d, ff)), f32((ff, d)),
        )
    emit(
        "predictor",
        M.predictor_fn,
        f32((s, d)), f32((d, M.PREDICTOR_HIDDEN)), f32((M.PREDICTOR_HIDDEN,)),
        *[f32((M.PREDICTOR_HIDDEN, e)) for _ in range(n_layers)],
    )

    # --- weights ----------------------------------------------------------
    print("[aot] packing weights.bin")
    manifest_weights = {}
    offset = 0
    with open(os.path.join(outdir, "weights.bin"), "wb") as f:
        for name in sorted(weights.keys()):
            arr = np.ascontiguousarray(weights[name], dtype="<f4")
            f.write(arr.tobytes())
            manifest_weights[name] = {
                "offset": offset,
                "shape": list(arr.shape),
            }
            offset += arr.nbytes
    print(f"[aot] weights.bin: {offset / 1e6:.1f} MB, "
          f"{len(manifest_weights)} tensors")

    # --- oracle -----------------------------------------------------------
    print("[aot] computing oracle outputs")
    rng = np.random.default_rng(12345)
    oracle_ids = rng.integers(0, v, size=(1, s)).astype(np.int32)
    hidden, routes = M.model_forward_ref(jnp.array(oracle_ids), weights, cfg)
    x0 = M.embed_fn(jnp.array(oracle_ids), jnp.array(weights["embed"]))
    # Per-artifact probes (prefix of flattened outputs).
    h_attn = M.attention_block_fn(
        x0,
        *(jnp.array(weights[f"layers.0.attn.{k}"]) for k in
          ("ln", "wq", "wk", "wv", "wo")),
        cfg,
    )
    xn, logits = M.router_block_fn(
        h_attn,
        jnp.array(weights["layers.0.moe.ln"]),
        jnp.array(weights["layers.0.moe.router"]),
    )
    bucket0 = cfg["ffn_buckets"][0]
    ffn_out = M.expert_ffn_fn(
        xn[:bucket0],
        jnp.array(weights["layers.0.experts.0.w_gate"]),
        jnp.array(weights["layers.0.experts.0.w_up"]),
        jnp.array(weights["layers.0.experts.0.w_down"]),
    )
    pred_logits = M.predictor_fn(
        x0,
        jnp.array(weights["predictor.w1"]),
        jnp.array(weights["predictor.b1"]),
        *[jnp.array(weights[f"predictor.head.{l}"]) for l in range(n_layers)],
    )

    def prefix(arr, n=16):
        return [float(x) for x in np.asarray(arr).reshape(-1)[:n]]

    oracle = {
        "ids": oracle_ids[0].tolist(),
        "embed_prefix": prefix(x0),
        "attention_prefix": prefix(h_attn),
        "router_xn_prefix": prefix(xn),
        "router_logits_prefix": prefix(logits),
        "expert_ffn_b%d_prefix" % bucket0: prefix(ffn_out),
        "predictor_prefix": prefix(pred_logits),
        "model_hidden_prefix": prefix(hidden),
        "routes_layer0_first32": np.asarray(routes[0, :32, 0]).tolist(),
        "predictor_accuracy": pred_acc,
    }
    with open(os.path.join(outdir, "oracle.json"), "w") as f:
        json.dump(oracle, f, indent=1)

    # --- manifest ---------------------------------------------------------
    manifest = {
        "config": cfg,
        "predictor_hidden": M.PREDICTOR_HIDDEN,
        "predictor_accuracy": pred_acc,
        "artifacts": artifacts,
        "weights": manifest_weights,
        "weights_file": "weights.bin",
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest.json written; done.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
