"""Layer 1 — Pallas router kernel: RMSNorm + router-logit GEMM.

The router is the point where the paper's whole mechanism triggers (the
token->expert mapping whose skew everything hinges on), so it is kept as a
fused Pallas kernel: per token tile, normalise then project to expert
logits. Top-k selection itself happens in the rust coordinator — routing
*policy* is Layer-3 territory (the coordinator may override dispatch based
on the duplication plan).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

T_TILE = 64


def _router_kernel(x_ref, lnw_ref, wr_ref, xn_ref, logits_ref, *, eps):
    x = x_ref[...]  # [T_TILE, D]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    xn = x * jax.lax.rsqrt(var + eps) * lnw_ref[...]
    xn_ref[...] = xn
    logits_ref[...] = xn @ wr_ref[...]  # [T_TILE, E]


@functools.partial(jax.jit, static_argnames=("t_tile", "eps"))
def router(x, ln_weight, w_router, *, t_tile=T_TILE, eps=1e-5):
    """Fused RMSNorm + router projection.

    x [T, D]; ln_weight [D]; w_router [D, E] -> (xn [T, D], logits [T, E]).
    Returns the normalised activations too — the expert FFN consumes them,
    so the coordinator never re-runs the norm.
    """
    t, d = x.shape
    d2, e = w_router.shape
    assert d == d2
    assert ln_weight.shape == (d,)
    assert t % t_tile == 0, f"tokens {t} not a multiple of {t_tile}"

    grid = (t // t_tile,)
    kernel = functools.partial(_router_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_tile, d), lambda ti: (ti, 0)),
            pl.BlockSpec((d,), lambda ti: (0,)),
            pl.BlockSpec((d, e), lambda ti: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t_tile, d), lambda ti: (ti, 0)),
            pl.BlockSpec((t_tile, e), lambda ti: (ti, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), x.dtype),
            jax.ShapeDtypeStruct((t, e), x.dtype),
        ],
        interpret=True,
    )(x, ln_weight, w_router)
