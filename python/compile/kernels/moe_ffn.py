"""Layer 1 — Pallas SwiGLU expert-FFN kernel.

The compute hot-spot of the serving path: every routed token slice runs
through one expert's SwiGLU FFN. The paper's systems run this as a CUDA
GEMM pipeline; per DESIGN.md §Hardware-Adaptation we re-think it for TPU:

* the (tokens x d_model x d_ff) loop nest is tiled into MXU-aligned blocks
  expressed with ``BlockSpec`` — the HBM<->VMEM schedule that CUDA code
  writes with threadblocks;
* the grid iterates (token-tile, ff-tile) with an accumulator pattern for
  the down-projection: the output block is indexed only by the token tile,
  so the ff grid axis is a reduction that accumulates in place and the full
  [T, F] activation never materialises in VMEM;
* ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
  custom-calls; real-TPU perf is *estimated* from the VMEM footprint + MXU
  utilisation in DESIGN.md §Perf.

VMEM budget at the default tiles (T_TILE=64, F_TILE=256, D<=512, fp32):
x 64*D + w_gate/w_up D*256*2 + w_down 256*D + out 64*D ~= 1.6 MB << 16 MB,
leaving room for the pipeline's double buffers.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly default tiles (128-aligned where the model dims allow).
T_TILE = 64
F_TILE = 256


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    """One (token-tile, ff-tile) grid step.

    Computes this ff-tile's partial SwiGLU contribution and accumulates
    ``silu(x@wg) * (x@wu) @ wd`` into the output block (which is the same
    VMEM block for every step of the ff axis — a revisited reduction).
    """
    ff_step = pl.program_id(1)

    @pl.when(ff_step == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [T_TILE, D]
    gate = x @ wg_ref[...]  # [T_TILE, F_TILE] on the MXU
    up = x @ wu_ref[...]
    act = gate * jax.lax.logistic(gate)  # SiLU
    o_ref[...] += (act * up) @ wd_ref[...]  # [T_TILE, D]


@functools.partial(jax.jit, static_argnames=("t_tile", "f_tile"))
def swiglu_ffn(x, w_gate, w_up, w_down, *, t_tile=T_TILE, f_tile=F_TILE):
    """SwiGLU expert FFN via the Pallas kernel.

    x [T, D]; w_gate/w_up [D, F]; w_down [F, D] -> [T, D].
    T must be a multiple of ``t_tile`` and F of ``f_tile`` (the AOT path
    pads token counts to bucket sizes, see rust runtime/bucket.rs).
    """
    t, d = x.shape
    d2, f = w_gate.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    assert w_up.shape == (d, f), w_up.shape
    assert w_down.shape == (f, d), w_down.shape
    assert t % t_tile == 0, f"tokens {t} not a multiple of {t_tile}"
    assert f % f_tile == 0, f"d_ff {f} not a multiple of {f_tile}"

    grid = (t // t_tile, f // f_tile)
    return pl.pallas_call(
        _swiglu_kernel,
        grid=grid,
        in_specs=[
            # x: one token tile, full D, re-read for every ff step.
            pl.BlockSpec((t_tile, d), lambda ti, fi: (ti, 0)),
            # w_gate / w_up: full D x one ff tile.
            pl.BlockSpec((d, f_tile), lambda ti, fi: (0, fi)),
            pl.BlockSpec((d, f_tile), lambda ti, fi: (0, fi)),
            # w_down: one ff tile x full D.
            pl.BlockSpec((f_tile, d), lambda ti, fi: (fi, 0)),
        ],
        # Output indexed by the token tile only: the ff axis reduces.
        out_specs=pl.BlockSpec((t_tile, d), lambda ti, fi: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, w_gate, w_up, w_down)


def vmem_bytes(t_tile=T_TILE, f_tile=F_TILE, d=256, dtype_bytes=4):
    """Static VMEM-footprint estimate for one grid step (DESIGN.md §Perf)."""
    x = t_tile * d
    wg = d * f_tile
    wu = d * f_tile
    wd = f_tile * d
    out = t_tile * d
    return (x + wg + wu + wd + out) * dtype_bytes
