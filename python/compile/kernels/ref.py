"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal for Layer 1: every kernel in this
package must match its reference here to float tolerance under pytest
(including hypothesis sweeps over shapes/dtypes/seeds in
``python/tests/test_kernels.py``).
"""

import jax.numpy as jnp


def silu_ref(x):
    """SiLU / swish: ``x * sigmoid(x)``."""
    return x * jnp.reciprocal(1.0 + jnp.exp(-x))


def swiglu_ffn_ref(x, w_gate, w_up, w_down):
    """SwiGLU expert FFN: ``silu(x @ w_gate) * (x @ w_up) @ w_down``.

    Shapes: x [T, D], w_gate [D, F], w_up [D, F], w_down [F, D] -> [T, D].
    """
    return (silu_ref(x @ w_gate) * (x @ w_up)) @ w_down


def router_logits_ref(x, w_router):
    """Router projection: ``x @ w_router``. x [T, D], w [D, E] -> [T, E]."""
    return x @ w_router


def rmsnorm_ref(x, weight, eps=1e-5):
    """RMSNorm over the last axis."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jnp.reciprocal(jnp.sqrt(var + eps)) * weight
